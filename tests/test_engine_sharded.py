"""2-D (queries x workers) engine dispatch: sharded/vmap equivalence,
threshold routing, and the bounded bucketed pack cache.

Multi-device cases run in subprocesses with forced host device counts
(the main pytest process keeps its single default device), mirroring
tests/test_distributed.py."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SkyConfig
from repro.core.datagen import generate
from repro.serve import engine as engine_mod
from repro.serve.engine import SkylineEngine, pack_trace_count

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_engine_matches_vmap_bitwise_8dev():
    """On a (2 x 4) mesh the sharded path must return bit-for-bit the
    vmap-only engine's buffers, for ragged inputs and several configs."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SkyConfig, parallel
        from repro.core.datagen import generate
        from repro.launch.mesh import make_engine_mesh
        from repro.serve.engine import SkylineEngine
        assert len(jax.devices()) == 8
        mesh = make_engine_mesh(2, 4)

        specs = [("uniform", 900), ("anticorrelated", 1400),
                 ("correlated", 1100), ("uniform", 2048), ("uniform", 700)]
        queries = [generate(dist, jax.random.PRNGKey(7 * i), n, 4)
                   for i, (dist, n) in enumerate(specs)]
        masks = [None, jnp.arange(1400) % 5 != 0, None, None, None]
        keys = [jax.random.PRNGKey(50 + i) for i in range(len(queries))]

        for cfg in [SkyConfig(strategy="sliced", p=8, capacity=2048,
                              block=64, bucket_factor=4.0),
                    SkyConfig(strategy="grid", p=16, capacity=2048,
                              block=64, bucket_factor=8.0,
                              rep_filter="sorted", noseq=True)]:
            plain = SkylineEngine(cfg, min_n_bucket=64)
            sharded = SkylineEngine(cfg, min_n_bucket=64, mesh=mesh,
                                    shard_threshold_n=64)
            a = plain.run(queries, masks=masks, keys=keys)
            b = sharded.run(queries, masks=masks, keys=keys)
            assert sharded.sharded_dispatched >= 1
            for (buf_a, st_a), (buf_b, st_b) in zip(a, b):
                np.testing.assert_array_equal(np.asarray(buf_a.points),
                                              np.asarray(buf_b.points))
                np.testing.assert_array_equal(np.asarray(buf_a.mask),
                                              np.asarray(buf_b.mask))
                assert int(buf_a.count) == int(buf_b.count)
                assert bool(buf_a.overflow) == bool(buf_b.overflow)
                assert int(st_a["n_valid"]) == int(st_b["n_valid"])
        # the sharded program compiled once per (cfg, shape) like the
        # vmap one — no retrace across calls of the same bucket
        before = parallel.trace_count("fused_batch")
        sharded.run(queries, masks=masks, keys=keys)
        assert parallel.trace_count("fused_batch") == before
        print("OK")
    """)
    assert "OK" in out


def test_sharded_engine_threshold_routes_small_buckets_to_vmap_8dev():
    out = _run("""
        import jax, numpy as np
        from repro.core import SkyConfig
        from repro.core.datagen import generate
        from repro.launch.mesh import make_engine_mesh
        from repro.serve.engine import SkylineEngine
        cfg = SkyConfig(strategy="sliced", p=8, capacity=512, block=64,
                        bucket_factor=4.0)
        eng = SkylineEngine(cfg, mesh=make_engine_mesh(2, 4),
                            min_n_bucket=64, shard_threshold_n=1024)
        small = [generate("uniform", jax.random.PRNGKey(i), 100, 4)
                 for i in range(3)]
        eng.run(small)
        assert eng.sharded_dispatched == 0, "below threshold must vmap"
        large = [generate("uniform", jax.random.PRNGKey(9 + i), 1500, 4)
                 for i in range(3)]
        eng.run(large)
        assert eng.sharded_dispatched == 1, "above threshold must shard"
        # mixed batch: one group per path, single run() call
        eng2 = SkylineEngine(cfg, mesh=make_engine_mesh(2, 4),
                             min_n_bucket=64, shard_threshold_n=1024)
        outs = eng2.run(small + large)
        assert len(outs) == 6 and eng2.sharded_dispatched == 1
        assert eng2.batches_dispatched == 2
        print("OK")
    """)
    assert "OK" in out


def test_run_scaled_routes_through_sharded_path_8dev():
    """Same-shape stacked views also shard at large N, and per-dim
    positive rescaling keeps front sizes unchanged."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import SkyConfig, parallel_skyline
        from repro.core.datagen import generate
        from repro.launch.mesh import make_engine_mesh
        from repro.serve.engine import SkylineEngine
        cfg = SkyConfig(strategy="sliced", p=8, capacity=2048, block=64,
                        bucket_factor=4.0)
        eng = SkylineEngine(cfg, mesh=make_engine_mesh(4, 2),
                            min_n_bucket=64, shard_threshold_n=1024)
        pts = generate("anticorrelated", jax.random.PRNGKey(3), 1600, 4)
        w = jnp.asarray(np.random.default_rng(0).uniform(0.5, 2.0, (3, 4)),
                        jnp.float32)
        base, _ = parallel_skyline(pts, cfg=cfg)
        base_n = int(base.count)
        outs = eng.run_scaled(pts, w)
        assert eng.sharded_dispatched == 1
        for buf, _ in outs:
            assert int(buf.count) == base_n
        print("OK")
    """)
    assert "OK" in out


def test_engine_mesh_shape_factoring():
    from repro.launch.mesh import engine_mesh_shape
    assert engine_mesh_shape(8, 8) == (1, 8)
    assert engine_mesh_shape(4, 8) == (2, 4)
    assert engine_mesh_shape(6, 8) == (4, 2)   # workers must divide p
    assert engine_mesh_shape(5, 8) == (8, 1)
    assert engine_mesh_shape(8, 1) == (1, 1)
    assert engine_mesh_shape(16, 6) == (3, 2)  # and the device count


def test_engine_rejects_mesh_without_engine_axes():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)  # axes (data, model)
    try:
        SkylineEngine(SkyConfig(), mesh=mesh)
    except ValueError as e:
        assert "queries" in str(e) or "workers" in str(e)
    else:
        raise AssertionError("expected ValueError for missing axes")


def test_sharded_engine_single_device_mesh_matches_vmap():
    """A degenerate (1 x 1) engine mesh exercises the full sharded code
    path in-process (shard_map, 2-D specs) and must still bit-match."""
    from repro.launch.mesh import make_engine_mesh
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=4.0)
    queries = [generate("uniform", jax.random.PRNGKey(i), 200 + 10 * i, 3)
               for i in range(3)]
    keys = [jax.random.PRNGKey(i) for i in range(3)]
    plain = SkylineEngine(cfg)
    sharded = SkylineEngine(cfg, mesh=make_engine_mesh(1, 1),
                            shard_threshold_n=64)
    a = plain.run(queries, keys=keys)
    b = sharded.run(queries, keys=keys)
    assert sharded.sharded_dispatched == 1
    for (buf_a, _), (buf_b, _) in zip(a, b):
        np.testing.assert_array_equal(np.asarray(buf_a.points),
                                      np.asarray(buf_b.points))
        np.testing.assert_array_equal(np.asarray(buf_a.mask),
                                      np.asarray(buf_b.mask))


def test_pack_cache_bounded_under_ragged_stream():
    """A stream of adversarially ragged batches compiles at most one pack
    program per (Q-bucket, N-bucket) pair — never one per exact size
    tuple (the pre-bucketed-pack behaviour this guards against)."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=128, block=64,
                    bucket_factor=4.0)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_q_bucket=4)
    rng = np.random.default_rng(0)
    before = pack_trace_count()
    n_buckets = set()
    for step in range(12):
        q = int(rng.integers(1, 5))            # all inside one Q-bucket
        sizes = rng.integers(33, 128, q)       # two N-buckets: 64, 128
        queries = [generate("uniform", jax.random.PRNGKey(100 * step + j),
                            int(n), 3) for j, n in enumerate(sizes)]
        engine.run(queries)
        n_buckets.update(
            engine_mod._next_bucket(int(n), 64) for n in sizes)
    assert pack_trace_count() - before <= len(n_buckets)
    assert len(n_buckets) <= 2


def test_pack_trace_counts_masked_separately_but_bounded():
    cfg = SkyConfig(strategy="sliced", p=4, capacity=128, block=64,
                    bucket_factor=4.0)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_q_bucket=4)
    before = pack_trace_count()
    for step in range(6):
        n = 40 + step * 3                      # distinct exact sizes
        pts = generate("uniform", jax.random.PRNGKey(step), n, 3)
        engine.run([pts], masks=[jnp.arange(n) % 2 == 0])
    # one masked pack program for the (qb=4, nb=64) bucket — the six
    # distinct exact lengths all hit it
    assert pack_trace_count() - before <= 1


def test_scaled_subspace_pack_cache_bounded_under_ragged_shapes():
    """`run_scaled` / `run_subspace` now ride the two-level bucketed
    pack: a multi-tenant stream of distinct exact (Q, N) shapes compiles
    at most one view-pack program per (kind, Q-bucket, N-bucket) — never
    one per exact shape (the eager-`jnp.pad` behaviour this replaces)."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=128, block=64,
                    bucket_factor=4.0)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_q_bucket=4)
    rng = np.random.default_rng(0)
    before = pack_trace_count()
    for step in range(10):
        n = int(rng.integers(33, 128))         # two N-buckets: 64, 128
        q = int(rng.integers(1, 5))            # one Q-bucket
        pts = generate("uniform", jax.random.PRNGKey(step), n, 3)
        w = jnp.asarray(rng.uniform(0.5, 2.0, (q, 3)), jnp.float32)
        dm = jnp.asarray(rng.random((q, 3)) < 0.7).at[:, 0].set(True)
        engine.run_scaled(pts, w)
        engine.run_subspace(pts, dm)
    # <= 2 buckets x 2 view kinds
    assert pack_trace_count() - before <= 4


def test_pack_equivalence_host_staging():
    """The bucketed (host-staged) pack is semantically identical to
    per-query execution: masked rows and padding never leak."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=256, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg)
    from repro.core import parallel_skyline
    pts = generate("anticorrelated", jax.random.PRNGKey(2), 150, 4)
    mask = jnp.arange(150) % 4 != 0
    key = jax.random.PRNGKey(77)
    (buf, _), = engine.run([pts], masks=[mask], keys=[key])
    ref, _ = parallel_skyline(pts, mask, cfg=cfg, key=key)
    np.testing.assert_array_equal(np.asarray(buf.points),
                                  np.asarray(ref.points))
    np.testing.assert_array_equal(np.asarray(buf.mask),
                                  np.asarray(ref.mask))


def test_calibrated_factorings_route_and_stay_bitwise_8dev():
    """`calibrate_shard_threshold(..., factorings=True)` stores a
    per-bucket (queries x workers) factoring; dispatch routes each
    bucket through its calibrated mesh and results stay bit-for-bit the
    vmap engine's."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SkyConfig
        from repro.core.datagen import generate
        from repro.launch.mesh import make_engine_mesh
        from repro.serve.engine import (SkylineEngine,
                                        calibrate_shard_threshold)
        assert len(jax.devices()) == 8
        cfg = SkyConfig(strategy="sliced", p=8, capacity=2048, block=128,
                        bucket_factor=1.5)
        engine = SkylineEngine(cfg, mesh=make_engine_mesh(2, 4),
                               min_n_bucket=64)
        rep = calibrate_shard_threshold(engine, bucket_sizes=(2048,),
                                        repeat=1)
        # every measured bucket carries a timed factoring set and a
        # winner stored on the engine
        (nb, t), = rep["measurements"].items()
        assert set(t["factorings"]) == {"8x1", "4x2", "2x4", "1x8"}
        assert engine.factorings[nb][:2] == tuple(
            int(x) for x in t["best_factoring"].split("x"))
        # the merge-topology column: both modes timed, winner stored
        assert set(t["merge"]) == {"flat", "tree"}
        assert engine.factorings[nb][2] == t["best_merge"]
        assert t["best_merge"] in ("flat", "tree")
        # force sharded routing through the calibrated factoring and
        # compare against the vmap engine bitwise
        engine.shard_threshold_n = 64
        plain = SkylineEngine(cfg, min_n_bucket=64)
        queries = [generate("anticorrelated", jax.random.PRNGKey(i),
                            2048, 4) for i in range(2)]
        keys = list(jax.random.split(jax.random.PRNGKey(5), 2))
        got = engine.run(queries, keys=keys)
        want = plain.run(queries, keys=keys)
        assert engine.sharded_dispatched >= 1
        mesh = engine._mesh_for(nb)
        assert (mesh.shape["queries"], mesh.shape["workers"]) \
            == engine.factorings[nb][:2]
        for (b, _), (r, _) in zip(got, want):
            np.testing.assert_array_equal(np.asarray(b.points),
                                          np.asarray(r.points))
            np.testing.assert_array_equal(np.asarray(b.mask),
                                          np.asarray(r.mask))
        print("OK")
    """)
    assert "OK" in out


def test_calibration_skips_factorings_for_d_dependent_strategies():
    """grid/angular derive p from d, so per-bucket factorings (keyed by
    bucket size alone) would be unsound — calibration still sets the
    threshold but stores none."""
    from repro.launch.mesh import make_engine_mesh
    from repro.serve.engine import calibrate_shard_threshold
    cfg = SkyConfig(strategy="grid", p=16, capacity=256, block=64,
                    bucket_factor=8.0)
    engine = SkylineEngine(cfg, mesh=make_engine_mesh(1, 1),
                           min_n_bucket=64)
    rep = calibrate_shard_threshold(engine, bucket_sizes=(64,), repeat=1)
    assert rep["factorings"] == {} and engine.factorings == {}
    assert "threshold_n" in rep and rep["measurements"]
