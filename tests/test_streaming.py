"""Streaming/incremental skyline maintenance (`repro.core.incremental`):
any chunking of a dataset — including duplicate and already-dominated
chunks — finalizes bit-for-bit equal to the one-shot fused pipeline, on
the single-device path, the degenerate in-process meshes, and (in a
subprocess) a real 8-device 2-D (queries x workers) mesh."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (SkyConfig, parallel, parallel_skyline,
                        skyline_mask_exact)
from repro.core import incremental as inc
from repro.core.datagen import generate
from repro.serve.engine import SkylineEngine

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _dataset(seed: int, n: int = 320, d: int = 4) -> jnp.ndarray:
    """Continuous random data salted with duplicates and dominated rows."""
    pts = generate("anticorrelated", jax.random.PRNGKey(seed), n, d)
    dup = pts[: n // 8]                       # exact duplicates
    dominated = jnp.clip(pts[: n // 8] + 0.25, 0.0, 1.25)  # strictly worse
    return jnp.concatenate([pts, dup, dominated])


def _assert_stream_equals_oneshot(cfg, pts, cuts, *, mesh=None):
    key = jax.random.PRNGKey(42)
    ref, _ = parallel_skyline(pts, cfg=cfg, key=key, mesh=mesh)
    state = inc.init_state(cfg, pts.shape[1], dtype=pts.dtype)
    ins = inc.insert_chunk_fn(cfg, mesh)
    for i in range(len(cuts) - 1):
        chunk = pts[cuts[i]:cuts[i + 1]]
        state, _ = ins(state, chunk, jnp.ones(chunk.shape[0], bool),
                       jax.random.fold_in(key, i))
    out = inc.finalize(state, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(out.points),
                                  np.asarray(ref.points))
    np.testing.assert_array_equal(np.asarray(out.mask),
                                  np.asarray(ref.mask))
    assert int(out.count) == int(ref.count)
    assert not bool(out.overflow) and not bool(ref.overflow)
    assert int(state.seen) == pts.shape[0]
    assert int(state.chunks) == len(cuts) - 1
    return out


@pytest.mark.parametrize("cfg", [
    SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
              bucket_factor=6.0),
    SkyConfig(strategy="grid", p=16, capacity=512, block=64,
              bucket_factor=8.0, rep_filter="sorted", noseq=True),
    SkyConfig(strategy="random", p=4, capacity=512, block=64,
              bucket_factor=6.0),
], ids=["sliced", "grid+noseq+rep", "random"])
def test_fixed_chunkings_bitwise_equal_oneshot(cfg):
    pts = _dataset(0)
    n = pts.shape[0]
    for cuts in ([0, n], [0, 64, n], [0, 32, 32, 160, 288, n]):
        _assert_stream_equals_oneshot(cfg, pts, cuts)


def test_duplicate_and_dominated_chunks():
    """Re-feeding already-seen members leaves the front unchanged (except
    duplicates joining it), and a fully dominated chunk is a no-op."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    pts = generate("anticorrelated", jax.random.PRNGKey(3), 200, 4)
    key = jax.random.PRNGKey(9)
    state = inc.init_state(cfg, 4)
    ins = inc.insert_chunk_fn(cfg)
    state, _ = ins(state, pts, jnp.ones(200, bool), key)
    base = inc.finalize(state, cfg=cfg)

    # a chunk of strictly dominated rows: nothing changes but `seen`
    dominated = jnp.clip(pts[:50] + 0.3, 0.0, 1.3)
    state, stats = ins(state, dominated, jnp.ones(50, bool),
                       jax.random.fold_in(key, 1))
    assert int(stats["evicted"]) == 0 and int(stats["inserted"]) == 0
    after = inc.finalize(state, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(after.points),
                                  np.asarray(base.points))
    assert int(state.seen) == 250

    # duplicates of current members join the front (neither copy
    # dominates the other), evicting nobody
    state, stats = ins(state, pts[:20], jnp.ones(20, bool),
                       jax.random.fold_in(key, 2))
    assert int(stats["evicted"]) == 0
    dup_members = int(np.asarray(base.mask & (jnp.sum(jnp.abs(
        base.points[:, None, :] - pts[None, :20, :]), -1) == 0).any(1)
    ).sum())
    assert int(state.count) == int(base.count) + dup_members


def test_masked_and_empty_chunks():
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    pts = _dataset(5, n=160)
    key = jax.random.PRNGKey(11)
    ref, _ = parallel_skyline(pts, cfg=cfg, key=key)
    state = inc.init_state(cfg, 4)
    ins = inc.insert_chunk_fn(cfg)
    half = pts.shape[0] // 2
    state, _ = ins(state, pts[:half], jnp.ones(half, bool), key)
    # an all-masked chunk must be a no-op on the front
    state, _ = ins(state, jnp.ones((32, 4), jnp.float32),
                   jnp.zeros(32, bool), jax.random.fold_in(key, 1))
    state, _ = ins(state, pts[half:], jnp.ones(pts.shape[0] - half, bool),
                   jax.random.fold_in(key, 2))
    out = inc.finalize(state, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(out.points),
                                  np.asarray(ref.points))
    assert int(state.seen) == pts.shape[0]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_random_chunking_bitwise_equal(seed):
    """Any random chunking (32-aligned cuts, so the insert program cache
    is shared across examples) finalizes bit-for-bit equal to one-shot,
    duplicates and dominated rows included."""
    rng = np.random.default_rng(seed)
    pts = _dataset(int(rng.integers(100)), n=256)
    n = pts.shape[0]
    grid = list(range(32, n, 32))
    k = int(rng.integers(0, min(6, len(grid))))
    cuts = [0] + sorted(rng.choice(grid, size=k, replace=False).tolist()) \
        + [n]
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0,
                    noseq=bool(rng.integers(2)))
    _assert_stream_equals_oneshot(cfg, pts, cuts)


@pytest.mark.parametrize("strategy", ["random", "grid", "sliced"])
def test_score_ties_still_bitwise_equal(strategy):
    """Quantized (tie-heavy) data: distinct points with equal monotone
    score reach the merge in different orders per chunking/partitioning,
    so bitwise invariance needs the total lexicographic tie-break in
    `canonical_order` — this guards it (quantized integer-grid data plus
    the x/y mirror pair pattern that maximizes exact score ties)."""
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.integers(0, 6, (192, 3)) / 6.0, jnp.float32)
    cfg = SkyConfig(strategy=strategy, p=4, capacity=512, block=64,
                    bucket_factor=48.0)
    _assert_stream_equals_oneshot(cfg, pts, [0, 48, 100, 192])
    _assert_stream_equals_oneshot(cfg, pts, [0, 191, 192])


def test_insert_compiles_once_per_chunk_shape():
    """Repeated same-shape chunks hit the jit cache — no per-chunk
    retrace (the acceptance bound: traces ~ #buckets, not #chunks)."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=336, block=64,
                    bucket_factor=6.0)  # unique cfg => fresh cache entry
    state = inc.init_state(cfg, 3)
    ins = inc.insert_chunk_fn(cfg)
    before = parallel.trace_count("insert")
    for i in range(6):
        chunk = generate("uniform", jax.random.PRNGKey(i), 128, 3)
        state, _ = ins(state, chunk, jnp.ones(128, bool),
                       jax.random.PRNGKey(100 + i))
    jax.block_until_ready(state.points)
    assert parallel.trace_count("insert") - before == 1


def test_engine_stream_matches_engine_run():
    """`open_stream`/`feed`/`snapshot` with ragged, idle, and masked
    feeds equals one-shot `engine.run` over each stream's history —
    bitwise, through the host-staged pack."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64)
    a = generate("anticorrelated", jax.random.PRNGKey(0), 300, 4)
    b = generate("uniform", jax.random.PRNGKey(1), 170, 4)
    stream = engine.open_stream(4, q=2)
    stream.feed([a[:100], b[:70]])
    stream.feed([a[100:240], None])          # stream 1 idle this round
    stream.feed([a[240:], b[70:]])
    snaps = stream.snapshot()

    (ra, _), (rb, _) = engine.run([a, b])
    for buf, ref in zip(snaps, (ra, rb)):
        np.testing.assert_array_equal(np.asarray(buf.points),
                                      np.asarray(ref.points))
        np.testing.assert_array_equal(np.asarray(buf.mask),
                                      np.asarray(ref.mask))
        assert int(buf.count) == int(ref.count)
    counters = stream.counters()
    assert counters["seen"].tolist() == [300, 170]
    assert counters["chunks"].tolist() == [3, 3]


def test_stream_pack_cache_bounded_under_ragged_feeds():
    from repro.serve.engine import pack_trace_count
    cfg = SkyConfig(strategy="sliced", p=4, capacity=128, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_q_bucket=4)
    stream = engine.open_stream(3, q=2)
    rng = np.random.default_rng(0)
    before_pack = pack_trace_count()
    before_ins = parallel.trace_count("insert_batch")
    for step in range(10):
        sizes = rng.integers(33, 128, 2)     # two N-buckets: 64, 128
        stream.feed([generate("uniform", jax.random.PRNGKey(100 * step + j),
                              int(s), 3) for j, s in enumerate(sizes)])
    assert pack_trace_count() - before_pack <= 2
    assert parallel.trace_count("insert_batch") - before_ins <= 2


def test_batched_stream_equals_per_stream_inserts():
    """The batched insert (Q live skylines, one dispatch) is bitwise the
    per-stream single insert."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=256, block=64,
                    bucket_factor=6.0)
    q, n, d = 3, 96, 4
    chunks = [generate("uniform", jax.random.PRNGKey(i), n, d)
              for i in range(q)]
    keys = jax.random.split(jax.random.PRNGKey(5), q)
    batch_state = inc.init_state(cfg, d, q=q)
    batch_state, _ = inc.insert_chunk_batch_fn(cfg)(
        batch_state, jnp.stack(chunks), jnp.ones((q, n), bool), keys)
    outs = inc.finalize(batch_state, cfg=cfg)
    ins = inc.insert_chunk_fn(cfg)
    for i in range(q):
        st_i, _ = ins(inc.init_state(cfg, d), chunks[i],
                      jnp.ones(n, bool), keys[i])
        ref = inc.finalize(st_i, cfg=cfg)
        np.testing.assert_array_equal(np.asarray(outs.points[i]),
                                      np.asarray(ref.points))
        assert int(outs.count[i]) == int(ref.count)


def test_streaming_2d_mesh_8dev():
    """On a real (2 x 4) queries x workers mesh: sharded batched inserts
    are bitwise equal to the vmap engine stream AND to one-shot recompute
    over the full history."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SkyConfig
        from repro.core.datagen import generate
        from repro.launch.mesh import make_engine_mesh
        from repro.serve.engine import SkylineEngine
        assert len(jax.devices()) == 8
        cfg = SkyConfig(strategy="sliced", p=8, capacity=1024, block=64,
                        bucket_factor=4.0)
        data = [generate("anticorrelated", jax.random.PRNGKey(i), 1500, 4)
                for i in range(2)]
        cuts = [0, 500, 900, 1500]

        plain = SkylineEngine(cfg, min_n_bucket=64)
        sharded = SkylineEngine(cfg, min_n_bucket=64,
                                mesh=make_engine_mesh(2, 4),
                                shard_threshold_n=64)
        streams = [e.open_stream(4, q=2, key=jax.random.PRNGKey(77))
                   for e in (plain, sharded)]
        for i in range(3):
            for s in streams:
                s.feed([d[cuts[i]:cuts[i + 1]] for d in data])
        assert sharded.sharded_dispatched == 3
        snap_p, snap_s = [s.snapshot() for s in streams]
        ref = plain.run(data)
        for bp, bs, (br, _) in zip(snap_p, snap_s, ref):
            np.testing.assert_array_equal(np.asarray(bp.points),
                                          np.asarray(bs.points))
            np.testing.assert_array_equal(np.asarray(bp.mask),
                                          np.asarray(bs.mask))
            np.testing.assert_array_equal(np.asarray(bs.points),
                                          np.asarray(br.points))
            assert int(bp.count) == int(bs.count) == int(br.count)
        print("OK")
    """)
    assert "OK" in out


def test_streaming_1d_mesh_single_device():
    """The 1-D workers mesh path of insert_chunk (shard_map in-process on
    one device) is bitwise the mesh-free path."""
    from repro.launch.mesh import make_worker_mesh
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    pts = _dataset(7, n=192)
    _assert_stream_equals_oneshot(cfg, pts, [0, 64, pts.shape[0]],
                                  mesh=make_worker_mesh(1))


def test_oneshot_noseq_order_is_canonical():
    """After the refactor both merge modes emit the canonical SFS score
    order, so sequential and NoSeq one-shot fronts carry the same member
    prefix (sets were always equal; now order is too)."""
    pts = generate("anticorrelated", jax.random.PRNGKey(8), 400, 4)
    seq = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    nsq = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0, noseq=True)
    a, _ = parallel_skyline(pts, cfg=seq)
    b, _ = parallel_skyline(pts, cfg=nsq)
    ca, cb = int(a.count), int(b.count)
    assert ca == cb
    np.testing.assert_array_equal(np.asarray(a.points[:ca]),
                                  np.asarray(b.points[:cb]))
    want = set(map(tuple, np.asarray(pts)[np.asarray(
        skyline_mask_exact(pts))]))
    assert set(map(tuple, np.asarray(a.points)[np.asarray(a.mask)])) == want


# --- union-size histogram: data-derived epoch_capacity ---------------------

def test_epoch_front_histogram_autosizes_epoch_capacity():
    """Streams record their per-epoch front sizes on counters()/close();
    once a (d, epochs) bucket has enough observations, a new windowed
    stream that left `epoch_capacity` unset gets the data-derived size —
    and its snapshots stay bitwise those of a full-capacity stream."""
    from repro.serve.api import StreamOptions
    from repro.serve.engine import SkylineEngine

    eng = SkylineEngine(SkyConfig())
    rng = np.random.default_rng(0)
    first = eng.open_stream(3, StreamOptions(q=2, window_epochs=4))
    assert first.epoch_capacity == 0  # no observations yet
    for e in range(4):
        first.feed([jnp.asarray(rng.random((200, 3)), jnp.float32)] * 2)
        if e < 3:  # a final tick would expire the first epoch's front
            first.tick()
    first.close()  # records 2 tenants x 4 epochs = 8 front sizes
    hist = eng.epoch_front_hist[(3, 4)]
    assert sum(hist.values()) >= 8 and all(s > 0 for s in hist)

    sug = eng.suggest_epoch_capacity(3, 4)
    assert sug > 0 and sug % eng.cfg.block == 0
    auto = eng.open_stream(3, StreamOptions(q=1, window_epochs=4))
    assert auto.epoch_capacity == sug
    # the knob, when set, always wins over the suggestion
    pinned = eng.open_stream(
        3, StreamOptions(q=1, window_epochs=4, epoch_capacity=512))
    assert pinned.epoch_capacity == 512
    # unbounded (non-windowed) streams never auto-size
    assert eng.open_stream(3, StreamOptions(q=1)).epoch_capacity == 0

    plain = SkylineEngine(SkyConfig())
    full = plain.open_stream(3, StreamOptions(q=1, window_epochs=4))
    rng2 = np.random.default_rng(7)
    for _ in range(4):
        ch = jnp.asarray(rng2.random((150, 3)), jnp.float32)
        auto.feed([ch])
        full.feed([ch])
        auto.tick()
        full.tick()
    fa, fb = auto.snapshot()[0], full.snapshot()[0]
    np.testing.assert_array_equal(np.asarray(fa.points),
                                  np.asarray(fb.points))
    np.testing.assert_array_equal(np.asarray(fa.mask), np.asarray(fb.mask))
    assert int(fa.count) == int(fb.count)


def test_epoch_front_suggestion_needs_enough_samples():
    from repro.serve.api import StreamOptions
    from repro.serve.engine import SkylineEngine

    eng = SkylineEngine(SkyConfig())
    assert eng.suggest_epoch_capacity(3, 4) == 0  # empty histogram
    eng.record_epoch_fronts(3, 4, np.array([[5, 0, 3]]))  # zeros dropped
    assert sum(eng.epoch_front_hist[(3, 4)].values()) == 2
    assert eng.suggest_epoch_capacity(3, 4) == 0  # < 8 samples
    eng.record_epoch_fronts(3, 4, np.full((2, 4), 10))
    assert eng.suggest_epoch_capacity(3, 4) == eng.cfg.block  # 2*10 -> 256
    # a suggestion that would not shrink below full capacity is withheld
    eng.record_epoch_fronts(5, 4, np.full((3, 4), 3000))
    assert eng.suggest_epoch_capacity(5, 4) == 0
