"""Kernel autotuner: table persistence, env/default resolution, the
calibration pass's bitwise gate, and the engine's 'auto' consultation
rules (explicit impl / pinned wtile always win)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.parallel import SkyConfig
from repro.kernels.tuning import (TuneEntry, TuningTable, calibrate_kernels,
                                  default_table, set_default_table,
                                  tuning_key)
from repro.serve.engine import SkylineEngine, SkylineRequest


@pytest.fixture(autouse=True)
def _clean_default_table():
    set_default_table(None)
    yield
    set_default_table(None)


def _table(block=128, wtile=128, ok=True):
    return TuningTable(entries={
        tuning_key("sweep", 4, jnp.float32):
            TuneEntry(block=block, wtile=wtile, time_us=1.0, impl="jnp",
                      bitwise_ok=ok)})


def test_table_json_roundtrip(tmp_path):
    t = _table()
    path = t.save(str(tmp_path / "sub" / "tuning.json"))
    t2 = TuningTable.load(path)
    assert t2.to_json() == t.to_json()
    assert t2.lookup("sweep", 4, jnp.float32).block == 128
    assert t2.lookup("sweep", 7, jnp.float32) is None
    assert len(t2) == 1


def test_env_var_loads_default_table(tmp_path, monkeypatch):
    path = _table(block=64, wtile=64).save(str(tmp_path / "t.json"))
    monkeypatch.setenv("REPRO_KERNEL_TUNING", path)
    set_default_table(None)  # re-arm the lazy load
    tab = default_table()
    assert tab is not None and tab.lookup("sweep", 4, "float32").block == 64
    # a broken path degrades to None, never raises
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "nope.json"))
    set_default_table(None)
    assert default_table() is None


def test_calibrate_kernels_quick():
    rep = calibrate_kernels(None, ds=(4,), n=512, p=2, capacity=256,
                            blocks=(64, 128), repeat=1, apply=True)
    table = rep["table"]
    assert len(table) >= 1 and rep["divergent"] == []
    entry = table.lookup("sweep", 4, jnp.float32)
    assert entry is not None and entry.bitwise_ok
    # the winner is the argmin of the measured (verified) candidates
    times = rep["keys"][tuning_key("sweep", 4, jnp.float32)]["times_us"]
    assert times[f"b{entry.block}/t{entry.wtile}"] == min(times.values())
    # apply=True with engine=None installs the process default
    assert default_table() is table


def test_engine_consults_table_only_for_auto():
    eng = SkylineEngine(SkyConfig())
    eng.kernel_tuning = _table()
    tuned = eng._cfg_for(None, 4, "float32")
    assert (tuned.block, tuned.wtile) == (128, 128)
    # value-equal configs share the compile-cache key
    assert tuned == dataclasses.replace(eng.cfg, block=128, wtile=128)
    # no entry for this (d, dtype) -> untouched config
    assert eng._cfg_for(None, 7, "float32") == eng.cfg
    # an explicit per-request impl bypasses tuning entirely
    assert eng._cfg_for("perpair", 4, "float32").wtile == 0
    # a non-'auto' engine impl is never overridden
    eng_jnp = SkylineEngine(SkyConfig(impl="jnp"))
    eng_jnp.kernel_tuning = _table()
    assert eng_jnp._cfg_for(None, 4, "float32") == eng_jnp.cfg
    # an explicitly pinned wtile wins over the table
    eng_pin = SkylineEngine(SkyConfig(wtile=64))
    eng_pin.kernel_tuning = _table()
    assert eng_pin._cfg_for(None, 4, "float32").wtile == 64
    # a divergent entry is never applied
    eng_bad = SkylineEngine(SkyConfig())
    eng_bad.kernel_tuning = _table(ok=False)
    assert eng_bad._cfg_for(None, 4, "float32") == eng_bad.cfg


def test_tuned_engine_answers_bitwise_identical():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.integers(0, 9, (700, 4)) / 9, jnp.float32)
    req = [SkylineRequest(data=pts)]
    eng = SkylineEngine(SkyConfig())
    eng.kernel_tuning = _table()
    plain = SkylineEngine(SkyConfig())
    (bt, _), (bp, _) = eng.submit_many(req)[0], plain.submit_many(req)[0]
    np.testing.assert_array_equal(np.asarray(bt.points),
                                  np.asarray(bp.points))
    np.testing.assert_array_equal(np.asarray(bt.mask), np.asarray(bp.mask))
    assert int(bt.count) == int(bp.count)
