"""Admission scheduling over streaming request pools: the second-layer
backfill of `StreamingAdmitter` (skyline of the non-front pool) and the
aging fronts of `WindowedAdmitter`."""

import jax.numpy as jnp
import numpy as np

from repro.core import SkyConfig
from repro.serve.engine import SkylineEngine
from repro.serve.scheduler import (Request, StreamingAdmitter,
                                   WindowedAdmitter)


def _engine():
    return SkylineEngine(SkyConfig(strategy="sliced", p=4, capacity=256,
                                   block=64, bucket_factor=6.0),
                         min_n_bucket=64)


def _requests(rows: np.ndarray) -> Request:
    rows = np.asarray(rows, np.float32)
    return Request(slack=jnp.asarray(rows[:, 0]),
                   neg_priority=jnp.asarray(rows[:, 1]),
                   cost=jnp.asarray(rows[:, 2]))


def _sky_rows(rows: np.ndarray) -> set:
    keep = []
    for i, t in enumerate(rows):
        dominated = any(np.all(s <= t) and np.any(s < t) for s in rows)
        if not dominated:
            keep.append(tuple(t))
    return set(keep)


def test_second_layer_is_skyline_of_non_front_pool():
    """After arbitrary offers (rejections AND evictions), the shadow
    front equals SKY(pool \\ front) computed from scratch."""
    rng = np.random.default_rng(0)
    adm = StreamingAdmitter(queues=1, engine=_engine(), backfill=True)
    pool = []
    for wave in range(4):
        rows = rng.random((12, 3)).astype(np.float32)
        if wave == 2:
            # a dominating wave that evicts earlier front members
            rows[:4] *= 0.1
        pool.append(rows)
        adm.offer([_requests(rows)])
    allrows = np.concatenate(pool)
    front = {tuple(r) for r in adm.fronts()[0]}
    assert front == _sky_rows(allrows)
    non_front = np.asarray([r for r in allrows if tuple(r) not in front],
                           np.float32)
    want_l2 = _sky_rows(non_front)
    got_l2 = {tuple(r) for r in adm.second_layer_fronts()[0]}
    assert got_l2 == want_l2


def test_admit_backfills_short_batches_from_second_layer():
    """A tiny front + a big batch size: admit() tops the batch up with
    second-layer rows, never short of batch_size while the pool has
    candidates, and never duplicates the front."""
    adm = StreamingAdmitter(queues=2, engine=_engine(), backfill=True)
    rng = np.random.default_rng(1)
    # one dominating request per queue guarantees a front of size 1
    dom = np.full((1, 3), 0.001, np.float32)
    rest = (rng.random((20, 3)) * 0.5 + 0.4).astype(np.float32)
    for qi_rows in (dom, rest):
        adm.offer([_requests(qi_rows)] * 2)
    fronts = adm.fronts()
    assert all(f.shape[0] == 1 for f in fronts)
    batches = adm.admit(6)
    for batch, front in zip(batches, fronts):
        assert batch.shape[0] == 6
        np.testing.assert_array_equal(batch[0], front[0])
        # backfilled rows come from the non-front pool's skyline
        l2 = _sky_rows(rest)
        assert all(tuple(r) in l2 for r in batch[1:])
    # without backfill the same schedule admits only the front
    plain = StreamingAdmitter(queues=1, engine=_engine())
    plain.offer([_requests(dom)])
    plain.offer([_requests(rest)])
    assert plain.admit(6)[0].shape[0] == 1


def test_windowed_admitter_fronts_age_out():
    """Requests only count toward the front for window_epochs ticks; an
    expired dominating wave un-dominates the survivors it suppressed."""
    adm = WindowedAdmitter(queues=1, window_epochs=2, engine=_engine())
    dominating = np.full((4, 3), 0.01, np.float32)
    weak = (np.random.default_rng(2).random((8, 3)) * 0.5 + 0.4
            ).astype(np.float32)
    adm.offer([_requests(dominating)])
    adm.tick()
    adm.offer([_requests(weak)])
    # window = {dominating, weak}: the front is the dominating wave
    front = adm.fronts()[0]
    assert {tuple(r) for r in front} == _sky_rows(dominating)
    # tick twice: the dominating wave ages out, weak requests resurface
    expired = adm.tick()
    assert expired
    front = adm.fronts()[0]
    assert {tuple(r) for r in front} == _sky_rows(weak)
    batch = adm.admit(3)[0]
    assert batch.shape[0] == 3
    assert all(tuple(r) in _sky_rows(weak) for r in batch)
    # one more tick and the weak wave is gone too: empty window admits
    # nothing (and does not crash on the empty front)
    adm.tick()
    assert adm.fronts()[0].shape[0] == 0
    assert adm.admit(3)[0].shape[0] == 0


def test_windowed_admitter_multi_queue_single_dispatch():
    eng = _engine()
    adm = WindowedAdmitter(queues=3, window_epochs=2, engine=eng)
    rng = np.random.default_rng(3)
    before = eng.batches_dispatched
    adm.offer([_requests(rng.random((6, 3)).astype(np.float32))
               for _ in range(3)])
    assert eng.batches_dispatched - before == 1  # one feed for 3 queues
    before = eng.batches_dispatched
    adm.tick()
    assert eng.batches_dispatched - before == 1  # one tick for 3 queues
    assert all(f.shape[0] >= 1 for f in adm.fronts())
