"""The static verifier (`repro.analysis`): each skylint rule fires on
its fixture violation (and ONLY there), suppressions and the baseline
are honored, the real tree gates clean, and the Layer-2 program
verifier holds its invariants on the traced suite."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.findings import load_baseline, write_baseline
from repro.analysis.lint import lint_paths

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")


def _write(tmp_path, rel, code):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    return str(path)


# one minimal violation per rule: (rule, relpath, source, violation line)
FIXTURES = {
    "R1": ("pipe/hot.py", """\
        import jax


        @jax.jit
        def entry(x):
            return helper(x)


        def helper(x):
            return x.item() + 1
        """, 10),
    "R2": ("serve/packer.py", """\
        import jax.numpy as jnp


        def pack(items):
            out = []
            for it in items:
                out.append(jnp.pad(it, (0, 3)))
            return out
        """, 7),
    "R3": ("pipe/caller.py", """\
        from repro.kernels.sfs.ops import sfs_sweep

        print(sfs_sweep)
        """, 1),
    "R4": ("pipe/meshy.py", """\
        from jax.experimental.shard_map import shard_map

        print(shard_map)
        """, 1),
    "R5": ("core/branchy.py", """\
        import jax
        import jax.numpy as jnp


        @jax.jit
        def entry(x):
            if jnp.max(x) > 0:
                return x
            return -x
        """, 7),
    "R6": ("serve/statefact.py", """\
        import jax


        def update_fn():
            def run(state, x):
                return state + x

            return jax.jit(run)
        """, 8),
}


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_each_rule_fires_exactly_on_its_fixture(tmp_path, rule):
    rel, code, line = FIXTURES[rule]
    path = _write(tmp_path, rel, code)
    findings = lint_paths([str(tmp_path)], repo_root=str(tmp_path))
    active = [f for f in findings if f.active]
    assert len(active) == 1, [str(f) for f in findings]
    f = active[0]
    assert f.rule == rule
    assert os.path.join(str(tmp_path), f.path) == path
    assert f.line == line
    assert f.hint  # every rule ships a fix-hint


def test_fixtures_do_not_cross_fire(tmp_path):
    """All fixtures together: one active finding per rule — no rule
    fires on another rule's fixture."""
    for rule, (rel, code, _) in FIXTURES.items():
        _write(tmp_path, rel, code)
    findings = [f for f in lint_paths([str(tmp_path)],
                                      repo_root=str(tmp_path)) if f.active]
    assert sorted(f.rule for f in findings) == sorted(FIXTURES)


def test_suppression_comment_same_line_and_line_above(tmp_path):
    rel, code, line = FIXTURES["R1"]
    code = code.replace("return x.item() + 1",
                        "return x.item() + 1  # skylint: disable=R1")
    _write(tmp_path, rel, code)
    rel4, code4, _ = FIXTURES["R4"]
    code4 = code4.replace(
        "from jax.experimental.shard_map import shard_map",
        "# legacy path kept for a vendored script\n"
        "        # skylint: disable=R4\n"
        "        from jax.experimental.shard_map import shard_map", 1)
    _write(tmp_path, rel4, code4)
    findings = lint_paths([str(tmp_path)], repo_root=str(tmp_path))
    assert len(findings) == 2
    assert all(f.suppressed and not f.active for f in findings)
    # a suppression for a DIFFERENT rule does not cover the finding
    _write(tmp_path, "pipe/wrong.py", """\
        import jax


        @jax.jit
        def entry(x):
            return helper(x)


        def helper(x):
            return x.item() + 1  # skylint: disable=R2
        """)
    findings = lint_paths([str(tmp_path / "pipe" / "wrong.py")],
                          repo_root=str(tmp_path))
    assert [f.rule for f in findings if f.active] == ["R1"]


def test_baseline_grandfathers_by_line_text(tmp_path):
    rel, code, _ = FIXTURES["R3"]
    _write(tmp_path, rel, code)
    first = lint_paths([str(tmp_path)], repo_root=str(tmp_path))
    bl = tmp_path / "baseline.json"
    assert write_baseline(first, str(bl)) == 1
    again = lint_paths([str(tmp_path)], repo_root=str(tmp_path),
                       baseline_keys=load_baseline(str(bl)))
    assert all(f.baselined and not f.active for f in again)
    # moving the offending line keeps it baselined (keyed on text)...
    _write(tmp_path, rel, "# a new leading comment\n"
           + textwrap.dedent(code))
    moved = lint_paths([str(tmp_path)], repo_root=str(tmp_path),
                       baseline_keys=load_baseline(str(bl)))
    assert all(f.baselined for f in moved if f.rule == "R3")
    # ...but a CHANGED offending line goes stale and gates again
    _write(tmp_path, rel,
           "from repro.kernels.dominance.ops import dominated_mask\n")
    stale = lint_paths([str(tmp_path)], repo_root=str(tmp_path),
                       baseline_keys=load_baseline(str(bl)))
    assert [f.rule for f in stale if f.active] == ["R3"]


def test_clean_tree_passes():
    """The gate on the real tree: zero active findings AND zero R1
    suppressions — the formerly sanctioned slab fits sync is retired
    (feed now overlays the pending record in-program and polls
    is_ready(); nothing on the serve path blocks on the device)."""
    findings = lint_paths([os.path.join(SRC, "repro")], repo_root=ROOT)
    active = [f for f in findings if f.active]
    assert active == [], [str(f) for f in active]
    r1_suppressed = [f for f in findings
                     if f.suppressed and f.rule == "R1"]
    assert r1_suppressed == [], \
        [str(f) for f in r1_suppressed]


def test_cli_exit_codes_and_json_report(tmp_path):
    """Non-zero exit + a JSON report naming rule and file:line on a
    violation; exit 0 on the clean tree (lint layer: fast, no jax)."""
    rel, code, line = FIXTURES["R1"]
    path = _write(tmp_path, rel, code)
    report = tmp_path / "report.json"
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--layer", "lint",
         "--paths", str(tmp_path), "--json", str(report),
         "--baseline", str(tmp_path / "none.json")],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=120)
    assert r.returncode == 1, r.stdout + r.stderr
    data = json.loads(report.read_text())
    (f,) = [f for f in data["layers"]["lint"]["findings"]
            if not f["suppressed"]]
    assert f["rule"] == "R1" and f["line"] == line
    # the CLI reports paths relative to the repo root
    assert os.path.normpath(os.path.join(ROOT, f["path"])) == path
    assert not data["ok"]

    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--layer", "lint"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr


def test_vmem_estimate_tracks_tiling():
    from repro.kernels.backend import vmem_estimate
    small = vmem_estimate(64, 512)
    big = vmem_estimate(512, 16384)
    assert small["sweep"] < big["sweep"]
    assert small["dominance"] < big["dominance"]
    assert big["window_rows"] == 16384
    # the documented kernel regime (W=4096, BC=512) sits under 16 MiB
    from repro.analysis.verifier import DEFAULT_VMEM_CAP
    doc = vmem_estimate(512, 4096)
    assert doc["sweep"] < DEFAULT_VMEM_CAP
    assert doc["dominance"] < DEFAULT_VMEM_CAP


def test_window_tiling_brings_large_capacity_under_vmem_cap():
    """The acceptance shape: capacity=16384 at block=512 (W x BC = 8.4M
    resident lanes) busts the 16 MiB/core cap untiled, and the SAME
    configuration passes it with a one-block window tile — tiling is
    what admits large windows, not a relaxed cap."""
    from repro.analysis.verifier import DEFAULT_VMEM_CAP
    from repro.kernels.backend import vmem_estimate
    untiled = vmem_estimate(512, 16_384)
    assert untiled["sweep"] > DEFAULT_VMEM_CAP  # previously rejected
    tiled = vmem_estimate(512, 16_384, wtile=512)
    assert tiled["sweep"] < DEFAULT_VMEM_CAP
    assert tiled["window_tile"] == 512
    assert tiled["window_rows"] == untiled["window_rows"] == 16_384
    # the estimate reports the *resident* footprint: tile-width test
    # and append intermediates, never the full window
    assert tiled["sweep"] < untiled["sweep"] / 8


def test_sweep_tiled_cell_passes_layer2_cap():
    """The `sweep_tiled` verifier cell carries the acceptance shape
    through the real Layer-2 gate: it must build, lower, and clear the
    VMEM cap that its untiled twin cannot."""
    from repro.analysis.verifier import verify_programs
    from repro.launch.cells import VERIFIER_EXTRA_CELLS
    spec = VERIFIER_EXTRA_CELLS["sweep_tiled"]
    assert spec["capacity"] == 16_384 and spec["wtile"] == 512
    report, errors = verify_programs(["sweep_tiled"], compile_hlo=False)
    assert errors == [], errors
    est = report["cells"]["sweep_tiled"]["vmem"]
    assert est["window_tile"] == 512
    # the same cell with the tile stripped must FAIL the cap
    untiled = dict(spec, wtile=0)
    from repro.launch.cells import VERIFIER_EXTRA_CELLS as cells_mod
    saved = cells_mod["sweep_tiled"]
    try:
        cells_mod["sweep_tiled"] = untiled
        _, errs = verify_programs(["sweep_tiled"], compile_hlo=False)
    finally:
        cells_mod["sweep_tiled"] = saved
    assert any("exceeds" in e and "sweep" in e for e in errs), errs


def test_program_verifier_invariants_hold():
    """Layer 2 on the traced suite (jaxpr census — no compile, any
    device count): no host primitives, workers-only collectives,
    Q-independence, collective-free vmap path, slab boundary census."""
    from repro.analysis.verifier import verify_programs
    report, errors = verify_programs(compile_hlo=False)
    assert errors == [], errors
    cells = report["cells"]
    assert set(cells) >= {"fused_p512", "batch_8x64", "stream_8x64",
                          "window_8x64", "window_tick", "slab_feed",
                          "slab_wave", "engine_vmap"}
    for name, rec in cells.items():
        assert rec["host_prims"] == [], name
        for prim, by_axis in rec["collectives"].items():
            assert set(by_axis) == {"workers"}, (name, prim, by_axis)
    assert cells["engine_vmap"]["collectives"] == {}
    assert cells["batch_8x64"]["collective_count_q"] == \
        cells["batch_8x64"]["collective_count_2q"]
    # the slab feed's program edge never carries the full state capacity
    from repro.core import SkyConfig
    from repro.core.incremental import state_capacity
    from repro.launch.cells import VERIFIER_EXTRA_CELLS
    spec = VERIFIER_EXTRA_CELLS["slab_feed"]
    cfg = SkyConfig(strategy="sliced", p=spec["p"],
                    capacity=spec["capacity"], block=spec["block"],
                    bucket_factor=1.5)
    assert state_capacity(cfg) not in cells["slab_feed"]["boundary_dims"]
    assert spec["rows"] in cells["slab_feed"]["boundary_dims"]
    # ...and neither does the coalesced serve-loop wave program's (its
    # pending-overlay operands ride at epoch_capacity, not C), and the
    # wave's merge communication is independent of the wave width Q
    wspec = VERIFIER_EXTRA_CELLS["slab_wave"]
    wcfg = SkyConfig(strategy="sliced", p=wspec["p"],
                     capacity=wspec["capacity"], block=wspec["block"],
                     bucket_factor=1.5)
    assert state_capacity(wcfg) not in \
        cells["slab_wave"]["boundary_dims"]
    assert wspec["rows"] in cells["slab_wave"]["boundary_dims"]
    assert cells["slab_wave"]["collective_count_q"] == \
        cells["slab_wave"]["collective_count_2q"]
