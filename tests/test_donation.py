"""Buffer donation (`SkyConfig.donate`) is a pure memory optimization:
every streaming/serving hot path produces bit-identical results with
donation on (in-place aliased updates, the default) and off (A/B copy
semantics) — across chunked inserts, window ticks, slab feeds,
coalesced serve-loop waves, and chained pending overlays with
promotion mid-chain. Also covers the ownership contract's observable
edges: a donated state is consumed (its buffers are deleted), and
`SkylineStream._pendings` drains eagerly under idle polling."""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SkyConfig
from repro.core import incremental as inc
from repro.core import windowed as win
from repro.core.datagen import generate
from repro.serve.engine import SkylineEngine
from repro.serve.loop import ServeLoop


def _cfg(donate: bool, **kw) -> SkyConfig:
    base = dict(strategy="sliced", p=4, capacity=256, block=64,
                bucket_factor=1.5, donate=donate)
    base.update(kw)
    return SkyConfig(**base)


def _dataset(seed: int, n: int = 256, d: int = 4) -> jnp.ndarray:
    """Random data salted with exact duplicates, dominated rows, and
    single-coordinate ties — the orderings donation must not perturb."""
    pts = generate("anticorrelated", jax.random.PRNGKey(seed), n, d)
    dup = pts[: n // 8]
    dominated = jnp.clip(pts[: n // 8] + 0.25, 0.0, 1.25)
    ties = pts[n // 8: n // 4].at[:, 0].set(pts[0, 0])
    return jnp.concatenate([pts, dup, dominated, ties])


def _assert_buffers_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.points),
                                  np.asarray(b.points))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------
# core: chunked insert / finalize
# --------------------------------------------------------------------------

def test_insert_finalize_bit_identical_donate_on_off():
    pts = _dataset(0)
    key = jax.random.PRNGKey(7)
    outs = []
    for donate in (True, False):
        cfg = _cfg(donate)
        state = inc.init_state(cfg, pts.shape[1])
        ins = inc.insert_chunk_fn(cfg)
        for i, cut in enumerate(range(0, pts.shape[0], 100)):
            chunk = pts[cut:cut + 100]
            state, _ = ins(state, chunk, jnp.ones(chunk.shape[0], bool),
                           jax.random.fold_in(key, i))
        outs.append(inc.finalize(state, cfg=cfg))
    _assert_buffers_equal(outs[0], outs[1])


def test_donated_insert_consumes_the_input_state():
    """The observable half of the single-owner protocol: with donation
    on the pre-update state's buffers are deleted (rebinding is
    mandatory); with donation off the old state stays readable."""
    pts = _dataset(1)[:100]
    mask = jnp.ones(pts.shape[0], bool)
    key = jax.random.PRNGKey(0)

    cfg = _cfg(True)
    state = inc.init_state(cfg, pts.shape[1])
    new, _ = inc.insert_chunk_fn(cfg)(state, pts, mask, key)
    jax.block_until_ready(new.points)
    with pytest.raises(RuntimeError):
        np.asarray(state.points)

    cfg = _cfg(False)
    state = inc.init_state(cfg, pts.shape[1])
    new, _ = inc.insert_chunk_fn(cfg)(state, pts, mask, key)
    jax.block_until_ready(new.points)
    assert np.asarray(state.points).shape == np.asarray(new.points).shape


# --------------------------------------------------------------------------
# core: windowed ring ticks
# --------------------------------------------------------------------------

def test_window_tick_bit_identical_donate_on_off():
    pts = _dataset(2)
    key = jax.random.PRNGKey(3)
    finals, fronts = [], []
    for donate in (True, False):
        cfg = _cfg(donate)
        state = win.init_window_state(cfg, pts.shape[1], epochs=4)
        tick = win.window_tick_fn(cfg)
        front = None
        for i, cut in enumerate(range(0, pts.shape[0], 80)):
            chunk = pts[cut:cut + 80]
            state, front, _ = tick(
                state, chunk, jnp.ones(chunk.shape[0], bool),
                jax.random.fold_in(key, i), jnp.bool_(i % 2 == 1))
        finals.append(state)
        fronts.append(front)
    _assert_trees_equal(finals[0], finals[1])
    _assert_trees_equal(fronts[0], fronts[1])


def test_advance_and_expire_bit_identical_donate_on_off():
    pts = _dataset(3)[:120]
    key = jax.random.PRNGKey(5)
    states = []
    for donate in (True, False):
        cfg = _cfg(donate)
        state = win.init_window_state(cfg, pts.shape[1], epochs=3)
        ins = win.insert_window_fn(cfg)
        state, _ = ins(state, pts, jnp.ones(pts.shape[0], bool), key)
        state, _ = win.advance_epoch(state, donate=donate)
        state, _ = ins(state, pts[:40], jnp.ones(40, bool),
                       jax.random.fold_in(key, 1))
        state, _ = win.expire_epoch(state, donate=donate)
        states.append(state)
    _assert_trees_equal(states[0], states[1])


# --------------------------------------------------------------------------
# serve: slab feeds, coalesced waves, chained pendings
# --------------------------------------------------------------------------

def _snap(engine_donate: bool, drive) -> list:
    cfg = _cfg(engine_donate, capacity=128)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_slab_rows=8)
    return drive(engine)


def test_slab_feed_bit_identical_donate_on_off():
    pts = _dataset(4)

    def drive(engine):
        s = engine.open_stream(pts.shape[1], q=1)
        s.feed([pts[:100]])
        s.feed([pts[100:250]])
        s.feed([pts[250:]])
        return s.snapshot()

    a, b = _snap(True, drive), _snap(False, drive)
    _assert_buffers_equal(a[0], b[0])


def test_windowed_slab_feed_and_tick_bit_identical():
    pts = _dataset(5)

    def drive(engine):
        s = engine.open_stream(pts.shape[1], q=1, window_epochs=3)
        s.feed([pts[:150]])
        s.tick()
        s.feed([pts[150:]])
        s.expire_epoch()
        return s.snapshot()

    a, b = _snap(True, drive), _snap(False, drive)
    _assert_buffers_equal(a[0], b[0])


def test_coalesced_wave_bit_identical_donate_on_off():
    pts = _dataset(6)
    chunks = [pts[i * 80:(i + 1) * 80] for i in range(4)]

    def drive(engine):
        sa = engine.open_stream(pts.shape[1], q=1)
        sb = engine.open_stream(pts.shape[1], q=1)
        with ServeLoop(engine, depth=1) as loop:
            loop.feed(sa, [chunks[0]])
            loop.feed(sb, [chunks[2]])
            loop.feed(sa, [chunks[1]])
            loop.feed(sb, [chunks[3]])
            loop.drain()
        return sa.snapshot() + sb.snapshot()

    a, b = _snap(True, drive), _snap(False, drive)
    _assert_buffers_equal(a[0], b[0])
    _assert_buffers_equal(a[1], b[1])


def test_chained_pending_overlays_bit_identical():
    """Repeated slot overflow chains pending records (promotion decided
    mid-chain once a deferred fits vector lands): the async path must
    stay bit-identical with donation on and off — the pending
    sub-states are shared overlays and are exactly the operands the
    single-owner protocol must NOT donate."""
    pts = _dataset(7, n=320)

    def drive(engine):
        s = engine.open_stream(pts.shape[1], q=1)
        for lo in range(0, 320, 80):
            s.feed([pts[lo:lo + 80]])  # overflows the 8-row slot fast
        out = [s.snapshot()[0]]
        s.feed([pts[:60]])             # keep feeding after promotion
        out.append(s.snapshot()[0])
        return out

    a, b = _snap(True, drive), _snap(False, drive)
    _assert_buffers_equal(a[0], b[0])
    _assert_buffers_equal(a[1], b[1])


# --------------------------------------------------------------------------
# eager pending drain (the idle-poll satellite)
# --------------------------------------------------------------------------

def test_stream_poll_drains_pendings_without_state_ops():
    cfg = _cfg(True, capacity=128)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_slab_rows=8)
    pts = _dataset(8)
    s = engine.open_stream(pts.shape[1], q=1)
    s.feed([pts])                       # front > 8 rows: pending record
    assert s._pendings
    deadline = time.monotonic() + 30
    while s.poll() and time.monotonic() < deadline:
        time.sleep(0.001)
    assert not s._pendings
    # the settled stream still answers exactly
    buf = s.snapshot()[0]
    assert int(np.asarray(buf.mask).sum()) > 0


def test_serve_loop_idle_polling_drains_pendings():
    """After a wave leaves a stream with pending records, the staging
    thread's idle tick keeps polling until the deferred fits vectors
    land — the full-capacity sub-states are released without ANY
    further stream operation."""
    cfg = _cfg(True, capacity=128)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_slab_rows=8)
    pts = _dataset(9)
    s = engine.open_stream(pts.shape[1], q=1)
    with ServeLoop(engine, depth=1) as loop:
        loop.feed(s, [pts]).wait(timeout=60)
        deadline = time.monotonic() + 30
        while s._pendings and time.monotonic() < deadline:
            time.sleep(0.005)
        assert not s._pendings
        assert not loop._watch
