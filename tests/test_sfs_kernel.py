"""Fused local-phase SFS sweep vs the per-pair reference: bit-for-bit
equivalence across backends (random data, ties, duplicates, masked rows,
overflow), interpret-mode Pallas validation, overflow subset semantics,
and the backend-layer plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.sfs import block_sfs, local_skyline_batch, naive_skyline_mask
from repro.kernels.backend import KernelSpec, resolve_spec

# 'interpret' runs the Pallas kernel body in interpret mode — the CPU
# validation path for the TPU sweep; 'jnp' is the fused single-dispatch
# blocked sweep. Both must be bit-for-bit the seed per-pair scan.
SWEEP_IMPLS = ["jnp", "interpret"]

SHAPES = [  # (P, n, d, capacity, block)
    (1, 1, 2, 4, 8),
    (2, 7, 3, 8, 4),
    (1, 100, 2, 100, 64),
    (3, 257, 5, 300, 64),
    (2, 513, 3, 64, 32),        # overflow: capacity << n
    (4, 300, 7, 128, 128),
    (1, 1000, 4, 2048, 256),
]


def _assert_bitwise_equal(got, want, ctx=""):
    for g, w, name in zip(got, want, ("points", "mask", "count",
                                      "overflow")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{name} differs {ctx}")


def _batch(rng, p, n, d, levels=5, mask_frac=0.2):
    # quantized coords -> plenty of exact ties and duplicate points
    pts = jnp.asarray(rng.integers(0, levels, (p, n, d)) / levels,
                      jnp.float32)
    mask = jnp.asarray(rng.random((p, n)) > mask_frac)
    return pts, mask


@pytest.mark.parametrize("p,n,d,cap,blk", SHAPES)
@pytest.mark.parametrize("impl", SWEEP_IMPLS)
def test_sweep_matches_perpair_reference(p, n, d, cap, blk, impl):
    rng = np.random.default_rng(p * 10_000 + n * 10 + d)
    pts, mask = _batch(rng, p, n, d)
    want = local_skyline_batch(pts, mask, capacity=cap, block=blk,
                               impl="perpair")
    got = local_skyline_batch(pts, mask, capacity=cap, block=blk,
                              impl=impl)
    _assert_bitwise_equal(got, want, f"impl={impl} shape={(p, n, d)}")


@pytest.mark.parametrize("impl", SWEEP_IMPLS + ["perpair"])
def test_sweep_matches_oracle(impl):
    rng = np.random.default_rng(7)
    pts, mask = _batch(rng, 3, 200, 4)
    buf = local_skyline_batch(pts, mask, capacity=200, block=64, impl=impl)
    for i in range(3):
        oracle = np.asarray(naive_skyline_mask(pts[i], mask[i]))
        want = set(map(tuple, np.asarray(pts[i])[oracle]))
        got = set(map(tuple,
                      np.asarray(buf.points[i])[np.asarray(buf.mask[i])]))
        assert got == want, (impl, i)
        # count counts member *rows* (duplicates kept), not distinct points
        assert int(buf.count[i]) == int(oracle.sum())
        assert not bool(buf.overflow[i])


@pytest.mark.parametrize("impl", SWEEP_IMPLS + ["perpair"])
def test_overflow_subset_semantics(impl):
    """When capacity < |SKY| the buffer is a *subset* of the true skyline
    (extra members dropped, never spurious ones added) and the overflow
    flag is set — via the batched sweep entry point."""
    rng = np.random.default_rng(11)
    pts = jnp.asarray(rng.random((2, 400, 5)), jnp.float32)
    mask = jnp.ones((2, 400), jnp.bool_)
    full = local_skyline_batch(pts, mask, capacity=400, block=64, impl=impl)
    small_cap = max(int(full.count.min()) // 3, 1)
    sky = local_skyline_batch(pts, mask, capacity=small_cap, block=64,
                              impl=impl)
    for i in range(2):
        assert bool(sky.overflow[i]), impl
        got = set(map(tuple,
                      np.asarray(sky.points[i])[np.asarray(sky.mask[i])]))
        want = set(map(tuple,
                       np.asarray(full.points[i])[np.asarray(full.mask[i])]))
        assert got <= want, impl
        assert len(got) <= small_cap + 63  # wcap rounds up to the block
        # the count still reports the scan's keep total, past capacity
        assert int(sky.count[i]) >= len(got)


@pytest.mark.parametrize("impl", SWEEP_IMPLS)
def test_overflow_via_block_sfs_wrapper(impl):
    rng = np.random.default_rng(13)
    pts = jnp.asarray(rng.random((400, 5)), jnp.float32)
    full = block_sfs(pts, capacity=400, block=64, impl=impl)
    small_cap = max(int(full.count) // 3, 1)
    sky = block_sfs(pts, capacity=small_cap, block=64, impl=impl)
    assert bool(sky.overflow)
    got = set(map(tuple, np.asarray(sky.points)[np.asarray(sky.mask)]))
    want = set(map(tuple, np.asarray(full.points)[np.asarray(full.mask)]))
    assert got <= want
    ref = block_sfs(pts, capacity=small_cap, block=64, impl="perpair")
    _assert_bitwise_equal(sky, ref, f"impl={impl} (wrapper, overflow)")


@pytest.mark.parametrize("impl", SWEEP_IMPLS)
def test_all_masked_and_empty_partitions(impl):
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.random((2, 64, 3)), jnp.float32)
    mask = jnp.zeros((2, 64), jnp.bool_).at[1, :5].set(True)
    want = local_skyline_batch(pts, mask, capacity=16, block=16,
                               impl="perpair")
    got = local_skyline_batch(pts, mask, capacity=16, block=16, impl=impl)
    _assert_bitwise_equal(got, want, f"impl={impl} (masked)")
    assert int(got.count[0]) == 0
    assert not bool(got.mask[0].any())


def test_wide_d_on_jnp_sweep():
    """d=20 exceeds the Pallas D_PAD layout but must work on the jnp
    sweep (and the per-pair reference, whose dominance impl is jnp)."""
    rng = np.random.default_rng(20)
    pts = jnp.asarray(rng.integers(0, 3, (2, 120, 20)) / 3.0, jnp.float32)
    mask = jnp.asarray(rng.random((2, 120)) > 0.1)
    want = local_skyline_batch(pts, mask, capacity=120, block=32,
                               impl="perpair")
    got = local_skyline_batch(pts, mask, capacity=120, block=32,
                              impl="jnp")
    _assert_bitwise_equal(got, want, "impl=jnp d=20")
    for i in range(2):
        oracle = set(map(tuple, np.asarray(pts[i])[np.asarray(
            naive_skyline_mask(pts[i], mask[i]))]))
        gset = set(map(tuple,
                       np.asarray(got.points[i])[np.asarray(got.mask[i])]))
        assert gset == oracle


def test_wide_d_rejected_by_pallas_sweep():
    pts = jnp.zeros((1, 16, 20), jnp.float32)
    with pytest.raises(ValueError, match="use impl='jnp'"):
        local_skyline_batch(pts, capacity=16, block=16, impl="interpret")


def test_negative_zero_bits_preserved():
    """The window buffer must preserve coordinate bits exactly — a -0.0
    skyline member must not come back as +0.0 from any impl (the Pallas
    append copies values through an integer-bit sum for this)."""
    pts = jnp.asarray([[[-0.0, 0.5], [0.25, 0.25], [0.5, -0.0],
                        [0.75, -1.0], [1.0, 1.0], [0.125, 0.625]]],
                      jnp.float32)
    ref = local_skyline_batch(pts, capacity=6, block=2, impl="perpair")
    assert np.signbit(np.asarray(ref.points)).any()  # a -0.0 survived
    for impl in SWEEP_IMPLS:
        got = local_skyline_batch(pts, capacity=6, block=2, impl=impl)
        np.testing.assert_array_equal(
            np.asarray(got.points).view(np.int32),
            np.asarray(ref.points).view(np.int32),
            err_msg=f"impl={impl} (raw bits)")


def test_backend_resolution():
    spec = resolve_spec("jnp")
    assert (spec.sweep, spec.dominance) == ("jnp", "jnp")
    assert resolve_spec("perpair").sweep == "perpair"
    assert resolve_spec("auto").name in ("jnp", "pallas")
    assert resolve_spec(spec) is spec  # specs pass through
    with pytest.raises(ValueError, match="unknown kernel backend"):
        resolve_spec("no-such-backend")
    with pytest.raises(ValueError, match="unknown sweep impl"):
        KernelSpec("bad", sweep="nope", dominance="jnp")


def test_block_size_changes_layout_not_membership():
    rng = np.random.default_rng(5)
    pts, mask = _batch(rng, 2, 300, 4)
    base = local_skyline_batch(pts, mask, capacity=300, block=64,
                               impl="jnp")
    for blk in (16, 128, 512):
        got = local_skyline_batch(pts, mask, capacity=300, block=blk,
                                  impl="jnp")
        np.testing.assert_array_equal(np.asarray(got.count),
                                      np.asarray(base.count))
        for i in range(2):
            a = set(map(tuple, np.asarray(got.points[i])[
                np.asarray(got.mask[i])]))
            b = set(map(tuple, np.asarray(base.points[i])[
                np.asarray(base.mask[i])]))
            assert a == b, blk


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(1, 120), st.integers(2, 6),
       st.integers(0, 3), st.sampled_from([16, 32, 64]),
       st.integers(0, 2 ** 31 - 1))
def test_hypothesis_sweep_parity(p, n, d, quant, blk, seed):
    """Property test: every sweep impl is bit-for-bit the per-pair
    reference over random data with heavy ties, duplicates, masked rows,
    and capacities small enough to overflow."""
    rng = np.random.default_rng(seed)
    levels = [3, 5, 17, 0][quant]
    if levels:
        pts = jnp.asarray(rng.integers(0, levels, (p, n, d)) / levels,
                          jnp.float32)
    else:
        pts = jnp.asarray(rng.random((p, n, d)), jnp.float32)
    mask = jnp.asarray(rng.random((p, n)) > 0.25)
    cap = int(rng.integers(1, n + 1))  # may force overflow
    want = local_skyline_batch(pts, mask, capacity=cap, block=blk,
                               impl="perpair")
    for impl in SWEEP_IMPLS:
        got = local_skyline_batch(pts, mask, capacity=cap, block=blk,
                                  impl=impl)
        _assert_bitwise_equal(
            got, want, f"impl={impl} p={p} n={n} d={d} cap={cap} blk={blk}")


# --- window tiling: wtile is pure schedule ---------------------------------
# 'gpu_interpret' runs the Triton-structured GPU kernel body (one grid
# program per partition, in-kernel candidate loop) in interpret mode —
# the CPU validation path for the GPU backend, always tiled internally.

TILED_IMPLS = ["jnp", "interpret", "gpu_interpret"]


@pytest.mark.parametrize("impl", TILED_IMPLS)
def test_tiled_sweep_matches_perpair_many_tiles(impl):
    """wcap many multiples of the tile: ties, duplicates, masked rows,
    and an overflowing capacity — every tiling bit-identical to the
    tile-free per-pair reference."""
    rng = np.random.default_rng(31)
    pts, mask = _batch(rng, 2, 500, 4)
    for cap, blk in ((512, 32), (96, 32)):  # 16 tiles; overflow at 96
        want = local_skyline_batch(pts, mask, capacity=cap, block=blk,
                                   impl="perpair")
        for wtile in (32, 64, 128):
            got = local_skyline_batch(pts, mask, capacity=cap, block=blk,
                                      impl=impl, wtile=wtile)
            _assert_bitwise_equal(
                got, want, f"impl={impl} cap={cap} wtile={wtile}")


@pytest.mark.parametrize("impl", TILED_IMPLS)
def test_window_exactly_one_tile(impl):
    """wtile == wcap degenerates to the untiled sweep — same bits."""
    rng = np.random.default_rng(33)
    pts, mask = _batch(rng, 2, 200, 3)
    want = local_skyline_batch(pts, mask, capacity=128, block=64,
                               impl="perpair")
    got = local_skyline_batch(pts, mask, capacity=128, block=64,
                              impl=impl, wtile=128)
    _assert_bitwise_equal(got, want, f"impl={impl} wtile==wcap")


@pytest.mark.parametrize("impl", TILED_IMPLS)
def test_append_straddles_tile_boundary(impl):
    """An antichain with ragged masked-row counts: every block's append
    lands mid-tile and spills into the next tile (kept counts never
    align with the tile width), exercising the two-store straddle path."""
    n, d = 100, 2
    # x + y = const: pairwise incomparable, so every unmasked row appends
    xs = np.linspace(0.0, 1.0, n, dtype=np.float32)
    pts = jnp.asarray(np.stack([xs, 1.0 - xs], axis=1))[None]
    rng = np.random.default_rng(37)
    mask = jnp.asarray(rng.random((1, n)) > 0.3)  # ragged kept counts
    want = local_skyline_batch(pts, mask, capacity=96, block=16,
                               impl="perpair")
    assert int(want.count[0]) == int(np.asarray(mask).sum())  # all kept
    for wtile in (16, 32):
        got = local_skyline_batch(pts, mask, capacity=96, block=16,
                                  impl=impl, wtile=wtile)
        _assert_bitwise_equal(got, want,
                              f"impl={impl} wtile={wtile} (straddle)")


def test_arbitrary_wtile_values_normalize():
    """wtile is a *request*: non-divisors of the window fall back to a
    valid tiling, 0 and >= wcap mean untiled — any integer must yield
    the reference bits (normalization is part of the schedule, never
    the result)."""
    rng = np.random.default_rng(41)
    pts, mask = _batch(rng, 1, 300, 4)
    want = local_skyline_batch(pts, mask, capacity=256, block=64,
                               impl="perpair")
    for wtile in (-1, 0, 7, 33, 64, 100, 128, 256, 10_000):
        got = local_skyline_batch(pts, mask, capacity=256, block=64,
                                  impl="jnp", wtile=wtile)
        _assert_bitwise_equal(got, want, f"wtile={wtile} (normalize)")


def test_tiled_negative_zero_bits_preserved():
    pts = jnp.asarray([[[-0.0, 0.5], [0.25, 0.25], [0.5, -0.0],
                        [0.75, -1.0], [1.0, 1.0], [0.125, 0.625]]],
                      jnp.float32)
    ref = local_skyline_batch(pts, capacity=6, block=2, impl="perpair")
    assert np.signbit(np.asarray(ref.points)).any()
    for impl in TILED_IMPLS:
        got = local_skyline_batch(pts, capacity=6, block=2, impl=impl,
                                  wtile=2)
        np.testing.assert_array_equal(
            np.asarray(got.points).view(np.int32),
            np.asarray(ref.points).view(np.int32),
            err_msg=f"impl={impl} wtile=2 (raw bits)")


def test_wide_d_on_gpu_sweep():
    """The GPU backend pads attribute rows instead of capping d — d=12
    must pass where the TPU Pallas layout rejects it."""
    rng = np.random.default_rng(43)
    pts = jnp.asarray(rng.integers(0, 3, (2, 120, 12)) / 3.0, jnp.float32)
    mask = jnp.asarray(rng.random((2, 120)) > 0.1)
    want = local_skyline_batch(pts, mask, capacity=120, block=32,
                               impl="perpair")
    got = local_skyline_batch(pts, mask, capacity=120, block=32,
                              impl="gpu_interpret")
    _assert_bitwise_equal(got, want, "impl=gpu_interpret d=12")


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 2), st.integers(1, 120), st.integers(2, 5),
       st.sampled_from([16, 32]), st.integers(0, 96),
       st.integers(0, 2 ** 31 - 1))
def test_hypothesis_tiled_parity(p, n, d, blk, wtile, seed):
    """Property: for ANY requested wtile (divisor or not, 0, oversized)
    every tiled impl is bit-for-bit the per-pair reference, including
    overflowing capacities."""
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.integers(0, 5, (p, n, d)) / 5, jnp.float32)
    mask = jnp.asarray(rng.random((p, n)) > 0.25)
    cap = int(rng.integers(1, n + 1))
    want = local_skyline_batch(pts, mask, capacity=cap, block=blk,
                               impl="perpair")
    for impl in TILED_IMPLS:
        got = local_skyline_batch(pts, mask, capacity=cap, block=blk,
                                  impl=impl, wtile=wtile)
        _assert_bitwise_equal(got, want, f"impl={impl} p={p} n={n} d={d} "
                                         f"cap={cap} blk={blk} wtile={wtile}")


def test_sweep_under_vmap_and_jit():
    """The engine vmaps the pipeline over queries: the fused sweep must
    compose with vmap+jit and stay bit-identical to the reference."""
    rng = np.random.default_rng(17)
    pts = jnp.asarray(rng.random((4, 2, 96, 3)), jnp.float32)  # (Q, P, n, d)
    mask = jnp.ones((4, 2, 96), jnp.bool_)

    def run(impl):
        f = jax.jit(jax.vmap(lambda x, m: local_skyline_batch(
            x, m, capacity=64, block=32, impl=impl)))
        return f(pts, mask)

    _assert_bitwise_equal(run("jnp"), run("perpair"), "vmap+jit")
