"""Distributed execution tests — subprocesses with forced host device
counts (the main pytest process keeps its single default device)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_shard_map_skyline_matches_oracle():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SkyConfig, parallel_skyline, skyline_mask_exact
        from repro.core.datagen import generate
        from repro.launch.mesh import make_worker_mesh
        assert len(jax.devices()) == 8
        mesh = make_worker_mesh()
        pts = generate("anticorrelated", jax.random.PRNGKey(3), 1200, 4)
        want = set(map(tuple, np.asarray(pts)[np.asarray(
            skyline_mask_exact(pts))]))
        for strat in ["random", "sliced", "grid", "angular"]:
            for noseq in [False, True]:
                cfg = SkyConfig(strategy=strat, p=16, capacity=2048,
                                block=64, bucket_factor=10.0,
                                rep_filter="sorted", noseq=noseq)
                buf, _ = parallel_skyline(pts, cfg=cfg, mesh=mesh)
                got = set(map(tuple,
                              np.asarray(buf.points)[np.asarray(buf.mask)]))
                assert not bool(buf.overflow), (strat, noseq)
                assert got == want, (strat, noseq, len(got), len(want))
        print("OK")
    """)
    assert "OK" in out


def test_tiled_sweep_on_8_device_mesh_matches_perpair():
    """The window-tiled sweep through the fused shard_mapped pipeline on
    a real 8-device workers mesh: every tiling bit-identical to the
    untiled program AND to the per-pair reference impl."""
    out = _run("""
        import dataclasses
        import numpy as np, jax
        from repro.core import SkyConfig, parallel_skyline
        from repro.core.datagen import generate
        from repro.launch.mesh import make_worker_mesh
        assert len(jax.devices()) == 8
        mesh = make_worker_mesh()
        pts = generate("anticorrelated", jax.random.PRNGKey(5), 1600, 4)
        base = SkyConfig(strategy="sliced", p=8, capacity=1024, block=128,
                         bucket_factor=4.0)
        ref, _ = parallel_skyline(pts, cfg=dataclasses.replace(
            base, impl="perpair"), mesh=mesh)
        for wtile in [0, 128, 256]:
            for impl in ["jnp", "gpu_interpret"]:
                cfg = dataclasses.replace(base, impl=impl, wtile=wtile)
                buf, _ = parallel_skyline(pts, cfg=cfg, mesh=mesh)
                np.testing.assert_array_equal(
                    np.asarray(buf.points), np.asarray(ref.points),
                    err_msg=f"{impl} wtile={wtile}")
                np.testing.assert_array_equal(
                    np.asarray(buf.mask), np.asarray(ref.mask))
                assert int(buf.count) == int(ref.count)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """Same batch, same init: a (2 data x 2 model) sharded train step must
    produce the same loss/params as the unsharded one."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import set_mesh
        from repro.configs import get_config, arch_rules
        from repro.data.pipeline import DataState, make_batch
        from repro.launch.mesh import make_local_mesh
        from repro.models import transformer as T
        from repro.models.common import init_params
        from repro.train.optim import OptConfig
        from repro.train.step import init_state, make_train_step

        cfg = get_config("yi-6b", smoke=True)
        opt = OptConfig(total_steps=10, warmup_steps=1)
        params = init_params(T.lm_plan(cfg), jax.random.PRNGKey(0))
        batch = make_batch(cfg, 8, 64, DataState(0, 0))

        state = init_state(params, opt)
        s1, m1 = jax.jit(make_train_step(cfg, opt))(state, batch)

        mesh = make_local_mesh(2, 2)
        rules = arch_rules(cfg, "train_4k", model_axis=2, data_axis=2)
        with set_mesh(mesh):
            bspec = NamedSharding(mesh, P("data"))
            batch_sh = jax.tree.map(
                lambda x: jax.device_put(x, bspec), batch)
            state2 = init_state(params, opt)
            step = jax.jit(make_train_step(cfg, opt, rules=rules,
                                           shard_activations=True))
            s2, m2 = step(state2, batch_sh)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3, (
            float(m1["loss"]), float(m2["loss"]))
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-3)
        print("OK")
    """, devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_smoke_cell_multipod():
    """The dry-run harness itself: one smoke cell on the real 512-device
    multi-pod mesh (lower + compile must succeed)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "yi-6b",
         "--shape", "train_4k", "--smoke", "--multi-pod", "--force"],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[ok]" in r.stdout, r.stdout


@pytest.mark.slow
def test_dryrun_skyline_cells_512_devices():
    """The fused skyline pipeline (1-D p=512 under both the flat and
    the log2(p)-round tree merge, the 2-D queries x workers batch
    program, the streaming chunk-insert program, the isolated fused
    local-phase sweep, and the sliding-window chunk-insert program)
    must lower + compile on the 512 forced host devices — the scale the
    1/4/8-device matrix can't reach."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--skyline",
         "--smoke", "--force"],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done: ok=6 err=0" in r.stdout, r.stdout


def test_elastic_checkpoint_restore_across_topology():
    """Save on 1 device, restore sharded onto a 2x2 mesh (elastic)."""
    out = _run("""
        import numpy as np, jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import restore, save
        from repro.configs import get_config, arch_rules
        from repro.launch.mesh import make_local_mesh
        from repro.models import transformer as T
        from repro.models.common import init_params, plan_pspecs
        from repro.sharding import named_shardings
        import tempfile, os

        cfg = get_config("yi-6b", smoke=True)
        plan = T.lm_plan(cfg)
        params = init_params(plan, jax.random.PRNGKey(0))
        d = tempfile.mkdtemp()
        save(d, 1, params)

        mesh = make_local_mesh(2, 2)
        rules = arch_rules(cfg, "train_4k", model_axis=2, data_axis=2)
        sh = named_shardings(plan_pspecs(plan, rules), mesh)
        got, step, _ = restore(d, params, shardings=sh)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # verify at least one leaf is actually sharded over the mesh
        shardings = {type(x.sharding).__name__
                     for x in jax.tree.leaves(got)}
        assert "NamedSharding" in shardings
        print("OK")
    """, devices=4)
    assert "OK" in out
