"""Pallas dominance kernel vs pure-jnp oracle: shape/dtype sweeps and
hypothesis property tests (interpret mode on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.dominance import dominated_mask, dominated_mask_ref

SHAPES = [(1, 1, 2), (7, 3, 2), (64, 64, 4), (130, 513, 5), (300, 40, 7),
          (512, 512, 8), (1000, 257, 3)]


@pytest.mark.parametrize("c,r,d", SHAPES)
@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_kernel_matches_oracle(c, r, d, impl):
    rng = np.random.default_rng(c * 1000 + r + d)
    cands = jnp.asarray(rng.random((c, d)), jnp.float32)
    refs = jnp.asarray(rng.random((r, d)), jnp.float32)
    mask = jnp.asarray(rng.random(r) > 0.25)
    want = dominated_mask_ref(cands, refs, mask)
    got = dominated_mask(cands, refs, mask, impl=impl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["jnp", "interpret"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_lower_tri_and_dtypes(impl, dtype):
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.random((200, 4)), dtype)
    want = dominated_mask_ref(pts, pts, None, lower_tri=True)
    got = dominated_mask(pts, pts, None, lower_tri=True, impl=impl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_block_size_invariance():
    rng = np.random.default_rng(3)
    cands = jnp.asarray(rng.random((700, 6)), jnp.float32)
    refs = jnp.asarray(rng.random((300, 6)), jnp.float32)
    base = dominated_mask(cands, refs, impl="jnp")
    for bc, br in [(128, 128), (256, 512), (512, 256)]:
        got = dominated_mask(cands, refs, impl="interpret", block_c=bc,
                             block_r=br)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_wide_d_supported_on_jnp_path():
    """d=20 exceeds the Pallas D_PAD sublane layout; the jnp path has no
    such layout, so the cap must only fire after impl resolution."""
    rng = np.random.default_rng(20)
    cands = jnp.asarray(rng.integers(0, 3, (150, 20)) / 3.0, jnp.float32)
    refs = jnp.asarray(rng.integers(0, 3, (90, 20)) / 3.0, jnp.float32)
    mask = jnp.asarray(rng.random(90) > 0.25)
    want = dominated_mask_ref(cands, refs, mask)
    got = dominated_mask(cands, refs, mask, impl="jnp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # lower_tri self-join too (the shape block_sfs uses)
    want = dominated_mask_ref(cands, cands, None, lower_tri=True)
    got = dominated_mask(cands, cands, None, lower_tri=True, impl="jnp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_wide_d_rejected_only_by_pallas_paths():
    pts = jnp.zeros((8, 20), jnp.float32)
    with pytest.raises(ValueError, match="use impl='jnp'"):
        dominated_mask(pts, pts, impl="interpret")


def test_all_masked_refs_dominate_nothing():
    rng = np.random.default_rng(5)
    cands = jnp.asarray(rng.random((50, 3)), jnp.float32)
    refs = jnp.zeros((20, 3), jnp.float32)  # would dominate everything
    mask = jnp.zeros((20,), bool)
    for impl in ["jnp", "interpret"]:
        got = dominated_mask(cands, refs, mask, impl=impl)
        assert not np.asarray(got).any()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 60), st.integers(1, 60), st.integers(2, 8),
       st.integers(0, 2 ** 31 - 1))
def test_hypothesis_parity(c, r, d, seed):
    rng = np.random.default_rng(seed)
    # quantized coords -> plenty of exact ties and duplicate points
    cands = jnp.asarray(rng.integers(0, 4, (c, d)) / 4.0, jnp.float32)
    refs = jnp.asarray(rng.integers(0, 4, (r, d)) / 4.0, jnp.float32)
    mask = jnp.asarray(rng.random(r) > 0.3)
    want = dominated_mask_ref(cands, refs, mask)
    got = dominated_mask(cands, refs, mask, impl="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_dominance_is_strict_partial_order(n, d, seed):
    """Irreflexive + antisymmetric + transitive on random data."""
    from repro.kernels.dominance import dominance_matrix_ref
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.integers(0, 3, (n, d)) / 3.0, jnp.float32)
    m = np.asarray(dominance_matrix_ref(pts, pts))
    assert not m.diagonal().any()                    # irreflexive
    assert not (m & m.T).any()                       # antisymmetric
    m2 = (m.astype(int) @ m.astype(int)) > 0         # transitivity
    assert not (m2 & ~m).any()
