"""Representative Filtering (paper §4.1), Grid Filtering (§3.2) and NoSeq
(§4.2, Proposition 2) — soundness and exactness properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import naive_skyline_mask
from repro.core.datagen import generate
from repro.core.dominance import region_volume
from repro.core.filtering import (filter_by_representatives, grid_filter,
                                  select_representatives)
from repro.core.parallel import SkyConfig, parallel_skyline


def _sky_set(pts, mask=None):
    return set(map(tuple, np.asarray(pts)[np.asarray(
        naive_skyline_mask(pts, mask))]))


@pytest.mark.parametrize("strategy", ["sorted", "region", "random"])
def test_representative_filtering_is_sound(strategy):
    """Filtering never deletes a skyline member (only dominated tuples)."""
    pts = generate("uniform", jax.random.PRNGKey(0), 400, 4)
    mask = jnp.ones(400, bool)
    reps, rmask = select_representatives(
        pts, mask, 16, strategy=strategy, key=jax.random.PRNGKey(1))
    new_mask = filter_by_representatives(pts, mask, reps, rmask)
    sky = naive_skyline_mask(pts)
    assert not np.asarray(sky & ~new_mask).any()
    # representatives are pairwise non-dominated after dedup
    from repro.kernels.dominance import dominated_mask_ref
    dom = dominated_mask_ref(reps, reps, rmask)
    assert not np.asarray(dom & rmask).any()


def test_sorted_reps_filter_more_than_random_on_average():
    drops = {}
    for strategy in ["sorted", "random"]:
        total = 0
        for seed in range(3):
            pts = generate("uniform", jax.random.PRNGKey(seed), 600, 4)
            mask = jnp.ones(600, bool)
            reps, rmask = select_representatives(
                pts, mask, 8, strategy=strategy,
                key=jax.random.PRNGKey(seed + 10))
            total += int((~filter_by_representatives(
                pts, mask, reps, rmask)).sum())
        drops[strategy] = total
    assert drops["sorted"] > drops["random"]


def test_region_volume():
    pts = jnp.array([[0.0, 0.0], [0.5, 0.5], [1.0, 1.0]], jnp.float32)
    np.testing.assert_allclose(np.asarray(region_volume(pts)),
                               [1.0, 0.25, 0.0])


def test_grid_filter_sound_and_effective():
    pts = generate("uniform", jax.random.PRNGKey(3), 2000, 4)
    mask = jnp.ones(2000, bool)
    gf = grid_filter(pts, mask, m=4)
    # soundness: no skyline member dropped
    sky = naive_skyline_mask(pts)
    assert not np.asarray(sky & ~gf.mask).any()
    # effectiveness: on uniform data a 4^4 grid filters a large share
    assert int(gf.dropped) > 500


def test_grid_filter_distribution_ordering():
    """Paper §5.1: correlated ~90% > uniform ~58% > anticorrelated ~16%."""
    frac = {}
    for dist in ["uniform", "correlated", "anticorrelated"]:
        pts = generate(dist, jax.random.PRNGKey(4), 3000, 4)
        gf = grid_filter(pts, jnp.ones(3000, bool), m=4)
        frac[dist] = float(gf.dropped) / 3000.0
    assert frac["correlated"] > frac["uniform"] > frac["anticorrelated"]


@pytest.mark.parametrize("strategy", ["random", "sliced", "grid", "angular"])
@pytest.mark.parametrize("dist", ["uniform", "anticorrelated"])
def test_proposition2_noseq_identity(strategy, dist):
    pts = generate(dist, jax.random.PRNGKey(5), 600, 4)
    cfg = SkyConfig(strategy=strategy, p=8, capacity=1024, block=64,
                    bucket_factor=8.0, noseq=True)
    buf, stats = parallel_skyline(pts, cfg=cfg)
    assert not bool(buf.overflow), stats
    got = set(map(tuple, np.asarray(buf.points)[np.asarray(buf.mask)]))
    assert got == _sky_set(pts)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(["random", "sliced", "grid", "angular"]),
       st.sampled_from([None, "sorted", "region"]),
       st.booleans(), st.integers(0, 2 ** 31 - 1))
def test_hypothesis_full_pipeline(strategy, rep, noseq, seed):
    """Prop 1 + Prop 2 + rep-filtering composed, random quantized data."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(30, 300))
    d = int(rng.integers(2, 6))
    pts = jnp.asarray(rng.integers(0, 8, (n, d)) / 8.0, jnp.float32)
    cfg = SkyConfig(strategy=strategy, p=4, capacity=max(n, 16), block=32,
                    bucket_factor=float(n), rep_filter=rep, rep_k=4,
                    noseq=noseq)
    buf, _ = parallel_skyline(pts, cfg=cfg)
    assert not bool(buf.overflow)
    got = set(map(tuple, np.asarray(buf.points)[np.asarray(buf.mask)]))
    assert got == _sky_set(pts)
