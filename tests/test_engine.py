"""Batched multi-query engine vs per-query execution, plus the fused
single-program execution model: retrace counting (compile-once across
same-shape queries) and no intermediate host transfers on the mesh path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SkyConfig, parallel_skyline, skyline_mask_exact
from repro.core import parallel
from repro.core.datagen import generate
from repro.serve.engine import SkylineEngine
from repro.serve.scheduler import Request, admit, admit_many

STRATEGIES = ["random", "sliced", "grid", "angular"]


def _sky_set(buf):
    return set(map(tuple, np.asarray(buf.points)[np.asarray(buf.mask)]))


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_engine_matches_per_query(strategy):
    """Engine-batched answers bit-match a per-query `parallel_skyline`
    loop: ragged sizes, one masked query, explicit per-query keys."""
    cfg = SkyConfig(strategy=strategy, p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg)
    specs = [("uniform", 100), ("anticorrelated", 180),
             ("correlated", 100), ("uniform", 250)]
    queries = [generate(dist, jax.random.PRNGKey(11 * i), n, 4)
               for i, (dist, n) in enumerate(specs)]
    masks = [None, jnp.arange(180) % 3 != 0, None, None]
    keys = [jax.random.PRNGKey(100 + i) for i in range(len(queries))]

    outs = engine.run(queries, masks=masks, keys=keys)
    assert engine.batches_dispatched >= 1
    for pts, mask, key, (buf, stats) in zip(queries, masks, keys, outs):
        ref, _ = parallel_skyline(pts, mask, cfg=cfg, key=key)
        assert not bool(buf.overflow) and not bool(ref.overflow)
        assert _sky_set(buf) == _sky_set(ref), strategy
        assert int(buf.count) == int(ref.count)


def test_engine_subspace_and_scaled_views():
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=4.0)
    engine = SkylineEngine(cfg)
    pts = generate("anticorrelated", jax.random.PRNGKey(3), 300, 4)

    # per-dim positive rescaling never changes skyline membership
    w = jnp.asarray(np.random.default_rng(0).uniform(0.5, 2.0, (3, 4)),
                    jnp.float32)
    base = _sky_set(parallel_skyline(pts, cfg=cfg)[0])
    for buf, _ in engine.run_scaled(pts, w):
        assert len(_sky_set(buf)) == len(base)

    # subspace views match the oracle on the zeroed copy
    dm = jnp.asarray([[True, True, False, False],
                      [True, True, True, True]])
    outs = engine.run_subspace(pts, dm)
    for row, (buf, _) in zip(dm, outs):
        view = jnp.where(row[None, :], pts, 0.0)
        want = set(map(tuple, np.asarray(view)[np.asarray(
            skyline_mask_exact(view))]))
        assert _sky_set(buf) == want


def test_fused_pipeline_compiles_once_across_same_shape_queries():
    """Repeated same-shape queries hit the jit cache: exactly one trace."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=333, block=64,
                    bucket_factor=4.0)  # unique cfg => fresh cache entry
    before = parallel.trace_count()
    for i in range(5):
        buf, _ = parallel_skyline(
            generate("uniform", jax.random.PRNGKey(i), 200, 3), cfg=cfg)
        jax.block_until_ready(buf.points)
    assert parallel.trace_count() - before == 1


def test_engine_compiles_once_per_size_bucket():
    """Q varying inside one Q-bucket and N varying inside one N-bucket
    reuse the same compiled batch program."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=334, block=64,
                    bucket_factor=4.0)  # unique cfg => fresh cache entry
    engine = SkylineEngine(cfg, min_n_bucket=256, min_q_bucket=4)
    before = parallel.trace_count()
    for qn in [(3, 200), (4, 256), (2, 140)]:
        q, n = qn
        outs = engine.run([generate("uniform", jax.random.PRNGKey(i), n, 3)
                           for i in range(q)])
        jax.block_until_ready(outs[0][0].points)
    assert parallel.trace_count() - before == 1


def test_mesh_path_has_no_intermediate_device_put(monkeypatch):
    """partition+local+merge run as one device-resident program: zero
    `jax.device_put` calls during a mesh execution, and the result is
    still exact."""
    from repro.launch.mesh import make_worker_mesh
    mesh = make_worker_mesh(1)  # single in-process CPU device
    cfg = SkyConfig(strategy="sliced", p=4, capacity=1024, block=64,
                    bucket_factor=6.0)
    pts = generate("anticorrelated", jax.random.PRNGKey(5), 600, 4)
    # warmup/compile outside the assertion window
    buf, _ = parallel_skyline(pts, cfg=cfg, mesh=mesh)
    jax.block_until_ready(buf.points)

    calls = []
    orig = jax.device_put
    monkeypatch.setattr(
        jax, "device_put",
        lambda *a, **k: (calls.append(a), orig(*a, **k))[1])
    buf, stats = parallel_skyline(pts, cfg=cfg, mesh=mesh)
    jax.block_until_ready(buf.points)
    assert calls == []

    want = set(map(tuple, np.asarray(pts)[np.asarray(
        skyline_mask_exact(pts))]))
    assert _sky_set(buf) == want
    assert int(stats["n_valid"]) == 600


def test_scheduler_admission_through_engine():
    rng = np.random.default_rng(0)

    def queue(n):
        return Request(
            slack=jnp.asarray(rng.exponential(10.0, n), jnp.float32),
            neg_priority=jnp.asarray(-rng.integers(0, 3, n), jnp.float32),
            cost=jnp.asarray(rng.integers(8, 64, n), jnp.float32))

    engine = SkylineEngine()
    queues = [queue(24), queue(24), queue(24)]
    many = admit_many(queues, 4, engine=engine)
    assert len(many) == 3
    for reqs, (picked, front) in zip(queues, many):
        one_picked, one_front = admit(reqs, 4, engine=engine)
        np.testing.assert_array_equal(np.asarray(front),
                                      np.asarray(one_front))
        np.testing.assert_array_equal(np.asarray(picked),
                                      np.asarray(one_picked))
        # no admitted request is dominated by a rejected one on the front
        assert int(np.asarray(front).sum()) >= 1
