"""Async continuous-batching serve loop (`repro.serve.loop`) + the
request-oriented engine surface (`repro.serve.api`): tickets resolve to
bit-exact results under adversarial async schedules, coalesced feed
waves equal serial feeds, deadline admission sheds/degrades with exact
accounting, and the deprecated entry points are bit-for-bit shims over
`submit_many`."""

import threading

import jax
import numpy as np
import pytest

from repro.core import SkyConfig
from repro.core.datagen import generate
from repro.serve.api import SkylineRequest, StreamOptions
from repro.serve.engine import SkylineEngine
from repro.serve.loop import ServeLoop


def _engine(**kw):
    cfg = SkyConfig(strategy="sliced", p=4, capacity=128, block=64,
                    bucket_factor=6.0)
    return SkylineEngine(cfg, min_n_bucket=64, **kw)


def _assert_results_equal(got, want):
    assert len(got) == len(want)
    for (b1, _), (b2, _) in zip(got, want):
        np.testing.assert_array_equal(np.asarray(b1.points),
                                      np.asarray(b2.points))
        np.testing.assert_array_equal(np.asarray(b1.mask),
                                      np.asarray(b2.mask))
        assert int(b1.count) == int(b2.count)


# --------------------------------------------------------------------------
# deprecation shims: the legacy entry points are bit-for-bit wrappers
# --------------------------------------------------------------------------

def test_run_is_a_bitwise_shim_over_submit_many():
    engine = _engine()
    rng = np.random.default_rng(0)
    queries = [np.asarray(rng.random((n, 3)), np.float32)
               for n in (40, 64, 17)]
    masks = [None, np.ones(64, bool), None]
    with pytest.deprecated_call():
        legacy = engine.run(queries, masks=masks)
    fresh = _engine()
    new = fresh.submit_many([
        SkylineRequest(data=x, mask=m,
                       key=jax.random.split(jax.random.PRNGKey(0), 3)[i])
        for i, (x, m) in enumerate(zip(queries, masks))])
    _assert_results_equal(legacy, new)


def test_run_scaled_and_run_subspace_are_bitwise_shims():
    engine = _engine()
    rng = np.random.default_rng(1)
    pts = np.asarray(rng.random((50, 4)), np.float32)
    weights = np.asarray(rng.uniform(0.5, 2.0, (3, 4)), np.float32)
    dims = np.asarray([[1, 1, 0, 0], [0, 1, 1, 1], [1, 0, 1, 0]], bool)
    with pytest.deprecated_call():
        ls = engine.run_scaled(pts, weights)
    with pytest.deprecated_call():
        lb = engine.run_subspace(pts, dims)
    fresh = _engine()
    ns = fresh.submit_many([SkylineRequest(data=pts, scale=w)
                            for w in weights])
    nb = fresh.submit_many([SkylineRequest(data=pts, subspace=m)
                            for m in dims])
    _assert_results_equal(ls, ns)
    _assert_results_equal(lb, nb)


def test_request_validation():
    pts = np.zeros((8, 3), np.float32)
    with pytest.raises(ValueError, match="(N, d)"):
        SkylineRequest(data=np.zeros(8, np.float32))
    with pytest.raises(ValueError, match="mutually exclusive"):
        SkylineRequest(data=pts, scale=np.ones(3),
                       subspace=np.ones(3, bool))
    with pytest.raises(ValueError, match="shape"):
        SkylineRequest(data=pts, scale=np.ones(4))
    with pytest.raises(Exception):
        SkylineRequest(data=pts, impl="no-such-backend")


def test_stream_options_validation_and_legacy_kwargs():
    with pytest.raises(ValueError, match="q="):
        StreamOptions(q=0)
    with pytest.raises(ValueError, match="window_epochs"):
        StreamOptions(window_epochs=0)
    with pytest.raises(ValueError, match="windowed"):
        StreamOptions(epoch_capacity=32)
    engine = _engine()
    with pytest.deprecated_call():
        s = engine.open_stream(3, q=2)
    assert s.q == 2
    with pytest.raises(ValueError, match="not both"):
        engine.open_stream(3, StreamOptions(q=1), q=2)
    with pytest.raises(TypeError, match="unexpected"):
        engine.open_stream(3, qq=2)
    s2 = engine.open_stream(3, StreamOptions(q=2, window_epochs=2,
                                             epoch_capacity=64))
    assert s2.q == 2 and s2.window_epochs == 2


# --------------------------------------------------------------------------
# the serve loop
# --------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 2])
def test_loop_answers_queries_bit_exact(depth):
    """Every ticket resolves to exactly what a synchronous submit of
    the same request returns, with or without dispatch-ahead."""
    engine = _engine()
    rng = np.random.default_rng(2)
    reqs = [SkylineRequest(data=np.asarray(rng.random((n, 3)), np.float32))
            for n in (30, 64, 10, 50)]
    with ServeLoop(engine, depth=depth, max_wave=2) as loop:
        tickets = [loop.submit(r) for r in reqs]
        loop.drain()
    assert all(t.status == "ok" for t in tickets)
    assert all(t.latency is not None and t.latency >= 0 for t in tickets)
    assert loop.stats["completed"] == len(reqs)
    fresh = _engine()
    want = [fresh.submit(r) for r in reqs]
    _assert_results_equal([t.result for t in tickets], want)


def test_coalesced_feed_wave_equals_serial_feeds():
    """Feeds for same-bucket streams fuse into one wave dispatch and
    stay bit-for-bit equal to feeding each stream serially."""
    engine = _engine()
    k = jax.random.PRNGKey(3)
    chunks = [generate("uniform", jax.random.fold_in(k, i), 48, 3)
              for i in range(5)]
    sa = engine.open_stream(3, StreamOptions(q=2))
    sb = engine.open_stream(3, StreamOptions(q=3))
    with ServeLoop(engine, depth=1) as loop:
        ta = loop.feed(sa, chunks[:2])
        tb = loop.feed(sb, chunks[2:])
        loop.drain()
    assert ta.status == tb.status == "ok"
    assert loop.stats["coalesced_feeds"] >= 1
    # serial reference on a fresh engine
    ref = _engine()
    ra = ref.open_stream(3, StreamOptions(q=2))
    rb = ref.open_stream(3, StreamOptions(q=3))
    ra.feed(chunks[:2])
    rb.feed(chunks[2:])
    for s, r in ((sa, ra), (sb, rb)):
        for b1, b2 in zip(s.snapshot(), r.snapshot()):
            np.testing.assert_array_equal(np.asarray(b1.points),
                                          np.asarray(b2.points))
            assert int(b1.count) == int(b2.count)


def test_adversarial_schedule_overflow_feeds_and_queries():
    """Interleaved overflowing feeds and queries under dispatch-ahead:
    promotion rides the async pending-record path (no blocking settle)
    and every result stays exact."""
    engine = _engine()
    rng = np.random.default_rng(4)
    s = engine.open_stream(2, StreamOptions(q=1))
    big = [generate("uniform", jax.random.fold_in(jax.random.PRNGKey(5),
                                                  i), 200, 2)
           for i in range(3)]  # anticorrelated-ish growth via volume
    qreqs = [SkylineRequest(data=np.asarray(rng.random((40, 3)),
                                            np.float32))
             for _ in range(3)]
    with ServeLoop(engine, depth=2, max_wave=1) as loop:
        tickets = []
        for chunk, qr in zip(big, qreqs):
            tickets.append(loop.feed(s, [chunk]))
            tickets.append(loop.submit(qr))
        loop.drain()
    assert all(t.status == "ok" for t in tickets)
    # the stream's front equals a serially fed reference stream
    buf, = s.snapshot()
    ref = _engine()
    rs = ref.open_stream(2, StreamOptions(q=1))
    for chunk in big:
        rs.feed([chunk])
    rbuf, = rs.snapshot()
    np.testing.assert_array_equal(np.asarray(buf.points),
                                  np.asarray(rbuf.points))
    assert int(buf.count) == int(rbuf.count)


def test_feed_ticket_carries_wave_stats():
    engine = _engine()
    s = engine.open_stream(3, StreamOptions(q=1))
    chunk = generate("uniform", jax.random.PRNGKey(6), 32, 3)
    with ServeLoop(engine) as loop:
        t = loop.feed(s, [chunk]).wait(timeout=60)
    assert t.status == "ok"
    assert int(np.asarray(t.result["chunk_arrivals"]).sum()) == 32


# --------------------------------------------------------------------------
# deadline admission: shed + degrade accounting
# --------------------------------------------------------------------------

def test_expired_deadline_is_shed_with_accounting():
    engine = _engine()
    data = np.asarray(np.random.default_rng(7).random((32, 3)),
                      np.float32)
    with ServeLoop(engine) as loop:
        now = loop._clock()
        doomed = loop.submit(SkylineRequest(data=data, deadline=now - 1))
        ok = loop.submit(SkylineRequest(data=data))
        doomed.wait(timeout=60)
        ok.wait(timeout=60)
        loop.drain()
    assert doomed.status == "shed" and doomed.result is None
    assert ok.status == "ok"
    assert loop.stats["shed"] == 1
    assert loop.stats["completed"] == 1


def test_degrade_answers_on_subsampled_data():
    engine = _engine()
    data = np.asarray(np.random.default_rng(8).random((64, 3)),
                      np.float32)
    with ServeLoop(engine, degrade=True) as loop:
        now = loop._clock()
        t = loop.submit(SkylineRequest(data=data, deadline=now - 1))
        t.wait(timeout=60)
    assert t.status == "ok" and t.degraded
    assert loop.stats["degraded"] == 1 and loop.stats["shed"] == 0
    want = _engine().submit(SkylineRequest(data=data[::2]))
    _assert_results_equal([t.result], [want])


def test_overload_sheds_oldest_deadline_first():
    """Deterministic unit test of the admission policy: backlog above
    max_queue sheds oldest-deadline-first, keeps undated items, and
    admits earliest-deadline-first (no threads involved)."""
    engine = _engine()
    loop = ServeLoop(engine, max_wave=4, max_queue=2,
                     clock=lambda: 100.0)
    loop._started = True  # enqueue without running the threads
    data = np.zeros((4, 2), np.float32)
    t200 = loop.submit(SkylineRequest(data=data, deadline=200.0))
    t150 = loop.submit(SkylineRequest(data=data, deadline=150.0))
    t300 = loop.submit(SkylineRequest(data=data, deadline=300.0))
    tnone = loop.submit(SkylineRequest(data=data))
    t250 = loop.submit(SkylineRequest(data=data, deadline=250.0))
    with loop._lock:
        batch = loop._admit_locked()
    assert [t.status for t in (t150, t200, t250)] == ["shed"] * 3
    assert all(t.done() for t in (t150, t200, t250))
    assert loop.stats["shed"] == 3
    # survivors admitted earliest-deadline-first, undated last
    assert batch == [t300, tnone]
    assert not loop._queue


def test_enqueue_requires_running_loop_and_close_flushes():
    engine = _engine()
    loop = ServeLoop(engine)
    with pytest.raises(RuntimeError, match="not running"):
        loop.submit(SkylineRequest(data=np.zeros((4, 2), np.float32)))
    # close() flushes whatever was accepted before it returns
    loop.start_serving()
    t = loop.submit(SkylineRequest(
        data=np.asarray(np.random.default_rng(9).random((16, 2)),
                        np.float32)))
    loop.close()
    assert t.done() and t.status == "ok"


def test_snapshot_never_blocks_on_inflight_wave():
    """The serving-path discipline end-to-end: an overflowing feed's
    fits vector may still be in flight when the next operation lands —
    the overlayed snapshot must answer exactly without a blocking
    resolve (the retired R1 sync)."""
    engine = _engine()
    s = engine.open_stream(2, StreamOptions(q=1))
    chunk = generate("uniform", jax.random.PRNGKey(10), 400, 2)
    s.feed([chunk])  # certainly overflows rows=64 slots
    buf, = s.snapshot()  # overlay path; no drain first
    assert int(np.asarray(buf.mask).sum()) > 0
    # and a drain + regular snapshot agrees with the overlay snapshot
    over = np.asarray(buf.points)[np.asarray(buf.mask)]
    s.drain()
    buf2, = s.snapshot()
    settled = np.asarray(buf2.points)[np.asarray(buf2.mask)]
    np.testing.assert_array_equal(np.sort(over, axis=0),
                                  np.sort(settled, axis=0))


# --------------------------------------------------------------------------
# wave-time model: the per-(d, dtype, rows-bucket) EWMA table
# --------------------------------------------------------------------------

def test_per_bucket_ewma_model_seeds_and_learns():
    """The admission model is a per-(d, dtype, rows-bucket) EWMA table:
    calibration hints seed it before any wave runs, completed waves
    update exactly the buckets they carried, and unseen buckets fall
    back to the catch-all scalar."""
    engine = _engine()
    seeded = (3, "float32", 64)  # the bucket the query below lands in
    engine.wave_time_hints = {seeded: 0.125}
    loop = ServeLoop(engine)
    assert loop._wave_time(seeded) == 0.125
    assert loop._wave_time((9, "float32", 64)) == 0.0  # cold, no scalar yet
    data = np.asarray(np.random.default_rng(12).random((40, 3)),
                      np.float32)
    s = engine.open_stream(3, StreamOptions(q=1))
    chunk = generate("uniform", jax.random.PRNGKey(13), 32, 3)
    with loop:
        loop.submit(SkylineRequest(data=data)).wait(timeout=60)
        loop.feed(s, [chunk]).wait(timeout=60)
        loop.drain()
    # the query wave blended a real observation into the seeded bucket
    assert loop._ewma_tab[seeded] != 0.125
    # the feed wave opened its own (d, dtype, slot-rows) bucket
    assert loop._ewma_tab[(s.d, np.dtype(s.dtype).name, s.rows)] > 0.0
    # and the catch-all scalar now backs cold buckets
    assert loop._wave_time((9, "float32", 64)) == loop._ewma > 0.0


def test_seeded_bucket_model_drives_admission():
    """Deterministic unit test: a calibration-seeded wave time for one
    bucket sheds exactly the requests that bucket's model says cannot
    meet their deadline (no threads involved)."""
    engine = _engine()
    engine.wave_time_hints = {(2, "float32", 64): 50.0}
    loop = ServeLoop(engine, clock=lambda: 100.0)
    loop._started = True  # enqueue without running the threads
    data = np.zeros((10, 2), np.float32)
    doomed = loop.submit(SkylineRequest(data=data, deadline=110.0))
    kept = loop.submit(SkylineRequest(data=data, deadline=200.0))
    with loop._lock:
        batch = loop._admit_locked()
    assert doomed.status == "shed" and loop.stats["shed"] == 1
    assert batch == [kept] and kept.status == "pending"


def test_concurrent_submitters_all_resolve():
    """Many intake threads racing one staging thread: every ticket
    resolves exactly once."""
    engine = _engine()
    rng = np.random.default_rng(11)
    datas = [np.asarray(rng.random((24, 3)), np.float32)
             for _ in range(12)]
    tickets = []
    tlock = threading.Lock()
    with ServeLoop(engine, depth=2, max_wave=3) as loop:
        def pump(xs):
            for x in xs:
                t = loop.submit(SkylineRequest(data=x))
                with tlock:
                    tickets.append(t)
        threads = [threading.Thread(target=pump, args=(datas[i::3],))
                   for i in range(3)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        loop.drain()
    assert len(tickets) == len(datas)
    assert all(t.status == "ok" for t in tickets)
    assert loop.stats["completed"] == len(datas)
