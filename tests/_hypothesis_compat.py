"""Optional-hypothesis shim for the property-based tests.

`hypothesis` is a declared test dependency (pyproject.toml) but not a
hard one: when it is missing, the property tests must *skip at run time*
while every plain pytest test in the same module still collects and
runs. Test modules import `given`, `settings`, `st` from here instead of
from hypothesis directly; with hypothesis absent the stand-in `given`
produces a test whose body is `pytest.importorskip("hypothesis")`, so it
reports as skipped with the canonical reason.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, others run
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg stand-in: pytest must not mistake the property
            # test's hypothesis-drawn parameters for fixtures
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """st.<anything>(...) placeholder; only ever passed to the no-op
        `given` above, never executed."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
