"""Tests run on the default single CPU device — the 512-device dry-run
environment is entered only by subprocess tests that spawn
repro.launch.dryrun (which sets XLA_FLAGS itself)."""

import os
import sys

# make `import repro` (and intra-tests helper imports) work without
# installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

os.environ.setdefault("JAX_PLATFORMS", "cpu")
