"""Sliding-window skyline maintenance (`repro.core.windowed`): for ANY
interleaving of chunk inserts, epoch advances, and expiries, the
merge-on-read `finalize` is bit-for-bit the one-shot fused skyline of
exactly the unexpired tuples — duplicates straddling epoch boundaries
and epochs expiring to empty included — on the single-device path, the
1-D in-process mesh, and (in a subprocess) a real 8-device 2-D mesh,
with the compiled-program count bounded per bucket."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import SkyConfig, parallel, parallel_skyline
from repro.core import windowed as win
from repro.core.datagen import generate
from repro.core.dominance import SENTINEL
from repro.core.filtering import select_representatives

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _dataset(seed: int, n: int = 256, d: int = 4) -> np.ndarray:
    """Random data salted with duplicates and dominated rows, so chunk
    boundaries regularly split identical points across epochs."""
    pts = generate("anticorrelated", jax.random.PRNGKey(seed), n, d)
    dup = pts[: n // 8]
    dominated = jnp.clip(pts[: n // 8] + 0.25, 0.0, 1.25)
    return np.asarray(jnp.concatenate([pts, dup, dominated]))


def _apply_schedule(cfg, epochs, ops, *, d=4, mesh=None):
    """Run a schedule against the device state AND a host-side model of
    the live window; return (finalized buffer, surviving rows)."""
    state = win.init_window_state(cfg, d, epochs=epochs)
    ins = win.insert_window_fn(cfg, mesh)
    model = [[]]  # oldest..newest live epochs; model[-1] is the head
    for i, op in enumerate(ops):
        if op[0] == "insert":
            chunk = jnp.asarray(op[1])
            state, _ = ins(state, chunk, jnp.ones(chunk.shape[0], bool),
                           jax.random.fold_in(jax.random.PRNGKey(7), i))
            model[-1].append(np.asarray(chunk))
        elif op[0] == "advance":
            state, _ = win.advance_epoch(state)
            model.append([])
            if len(model) > epochs:
                model.pop(0)
        else:  # expire
            state, _ = win.expire_epoch(state)
            if len(model) > 1:
                model.pop(0)
            else:
                model[0] = []
    out = win.finalize(state, cfg=cfg)
    survivors = [r for epoch in model for c in epoch for r in c]
    return out, np.asarray(survivors, np.float32).reshape(-1, d), state


def _assert_window_equals_oneshot(cfg, epochs, ops, *, d=4, mesh=None):
    out, survivors, state = _apply_schedule(cfg, epochs, ops, d=d,
                                            mesh=mesh)
    if survivors.shape[0] == 0:
        assert int(out.count) == 0
        assert not bool(out.mask.any())
        assert not bool(jnp.any(jnp.isnan(out.points)))
        return out
    ref, _ = parallel_skyline(jnp.asarray(survivors), cfg=cfg,
                              key=jax.random.PRNGKey(42), mesh=mesh)
    np.testing.assert_array_equal(np.asarray(out.points),
                                  np.asarray(ref.points))
    np.testing.assert_array_equal(np.asarray(out.mask),
                                  np.asarray(ref.mask))
    assert int(out.count) == int(ref.count)
    assert not bool(out.overflow) and not bool(ref.overflow)
    return out


@pytest.mark.parametrize("cfg", [
    SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
              bucket_factor=6.0),
    SkyConfig(strategy="grid", p=16, capacity=512, block=64,
              bucket_factor=8.0, rep_filter="sorted", noseq=True),
    SkyConfig(strategy="random", p=4, capacity=512, block=64,
              bucket_factor=6.0),
], ids=["sliced", "grid+noseq+rep", "random"])
def test_fixed_schedules_bitwise_equal_oneshot(cfg):
    pts = _dataset(0)
    c = [pts[i * 64:(i + 1) * 64] for i in range(5)]
    schedules = [
        # fill the ring without expiry
        [("insert", c[0]), ("advance",), ("insert", c[1]), ("advance",),
         ("insert", c[2])],
        # ring wraps: epoch 0 expires, duplicates of its rows live on
        [("insert", c[0]), ("advance",), ("insert", c[1]), ("advance",),
         ("insert", c[2]), ("advance",), ("insert", c[0][:32]),
         ("insert", c[3])],
        # explicit expiry between inserts
        [("insert", c[0]), ("insert", c[1]), ("advance",), ("insert", c[2]),
         ("expire",), ("insert", c[4])],
    ]
    for ops in schedules:
        _assert_window_equals_oneshot(cfg, 3, ops)


def test_duplicates_straddling_epoch_boundary():
    """The same rows inserted into two epochs: expiring the older epoch
    must keep the younger copies on the front (retained candidates make
    the expiry exact)."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    pts = _dataset(3, n=128)
    dup = pts[:48]  # rows present in epoch 0 AND epoch 1
    ops = [("insert", pts[:96]), ("advance",), ("insert", dup),
           ("insert", pts[96:]), ("advance",)]
    # epochs=2: the final advance wraps the ring and expires epoch 0
    out = _assert_window_equals_oneshot(cfg, 2, ops)
    # the duplicated prefix arrived again in the surviving epoch, so the
    # front must still contain every skyline member of `dup`
    ref, _ = parallel_skyline(jnp.asarray(np.concatenate([dup, pts[96:]])),
                              cfg=cfg)
    np.testing.assert_array_equal(np.asarray(out.points),
                                  np.asarray(ref.points))


def test_epoch_expiring_to_empty_and_reuse():
    """Expiring every epoch empties the window (count==0, no NaNs), and
    the ring keeps absorbing new chunks afterwards."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    pts = _dataset(5, n=128)
    state = win.init_window_state(cfg, 4, epochs=3)
    ins = win.insert_window_fn(cfg)
    state, _ = ins(state, jnp.asarray(pts[:64]), jnp.ones(64, bool),
                   jax.random.PRNGKey(0))
    state, _ = win.advance_epoch(state)
    state, _ = ins(state, jnp.asarray(pts[64:128]), jnp.ones(64, bool),
                   jax.random.PRNGKey(1))
    for _ in range(4):  # more expiries than live epochs: stays clamped
        state, _ = win.expire_epoch(state)
    out = win.finalize(state, cfg=cfg)
    assert int(out.count) == 0 and not bool(out.mask.any())
    assert not bool(jnp.any(jnp.isnan(out.points)))
    assert int(state.active) == 1
    # the emptied window is still live: feed it again
    state, _ = ins(state, jnp.asarray(pts[96:160]), jnp.ones(64, bool),
                   jax.random.PRNGKey(2))
    out = win.finalize(state, cfg=cfg)
    ref, _ = parallel_skyline(jnp.asarray(pts[96:160]), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(out.points),
                                  np.asarray(ref.points))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_random_interleavings_bitwise_equal(seed):
    """Random insert/advance/expire interleavings (64-row chunks drawn
    with replacement — duplicates regularly straddle epoch boundaries)
    finalize bit-for-bit equal to the one-shot skyline of the surviving
    tuples; all-expired windows finalize empty without NaNs."""
    rng = np.random.default_rng(seed)
    pts = _dataset(int(rng.integers(100)), n=192)
    epochs = int(rng.integers(2, 5))
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0, noseq=bool(rng.integers(2)))
    ops = []
    for _ in range(int(rng.integers(3, 9))):
        r = rng.random()
        if r < 0.55:
            lo = int(rng.integers(0, pts.shape[0] - 64))
            ops.append(("insert", pts[lo:lo + 64]))
        elif r < 0.85:
            ops.append(("advance",))
        else:
            ops.append(("expire",))
    _assert_window_equals_oneshot(cfg, epochs, ops)


def test_score_ties_across_expiry_still_bitwise_equal():
    """Quantized (tie-heavy) data across epoch boundaries and expiry:
    bitwise invariance still needs only the canonical total order."""
    rng = np.random.default_rng(3)
    pts = np.asarray(rng.integers(0, 6, (192, 3)) / 6.0, np.float32)
    for strategy in ("random", "grid", "sliced"):
        cfg = SkyConfig(strategy=strategy, p=4, capacity=512, block=64,
                        bucket_factor=48.0)
        ops = [("insert", pts[:64]), ("advance",), ("insert", pts[:64]),
               ("insert", pts[64:128]), ("advance",),
               ("insert", pts[128:]), ("advance",)]
        _assert_window_equals_oneshot(cfg, 2, ops, d=3)


def test_window_programs_compile_once():
    """One compiled insert and one compiled merge-on-read serve every
    head position and expiry schedule (ring scalars are traced, so the
    trace count is bounded by the shape buckets, not the schedule)."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=320, block=64,
                    bucket_factor=6.0)  # unique cfg => fresh jit cache
    state = win.init_window_state(cfg, 3, epochs=4)
    ins = win.insert_window_fn(cfg)
    before_i = parallel.trace_count("winsert")
    before_m = parallel.trace_count("wmerge")
    before_t = parallel.trace_count("wtick")
    for i in range(10):
        chunk = generate("uniform", jax.random.PRNGKey(i), 96, 3)
        state, _ = ins(state, chunk, jnp.ones(96, bool),
                       jax.random.PRNGKey(100 + i))
        if i % 2:
            state, _ = win.advance_epoch(state)
        else:
            win.finalize(state, cfg=cfg)
    state, _ = win.expire_epoch(state)
    jax.block_until_ready(state.points)
    assert parallel.trace_count("winsert") - before_i == 1
    assert parallel.trace_count("wmerge") - before_m == 1
    assert parallel.trace_count("wtick") - before_t == 2  # advance+expire


def test_fused_tick_equals_separate_ops():
    """`window_tick_fn` (rotate + insert + merge-on-read in ONE
    dispatch) is bitwise the three-dispatch path, for both tick kinds
    (advance traced as data), and with epoch slots sized below the
    window capacity (`epoch_capacity`)."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    pts = _dataset(9, n=192)
    tick = win.window_tick_fn(cfg)
    ins = win.insert_window_fn(cfg)
    for ecap in (0, 64):
        fused = win.init_window_state(cfg, 4, epochs=3,
                                      epoch_capacity=ecap)
        plain = win.init_window_state(cfg, 4, epochs=3,
                                      epoch_capacity=ecap)
        for t in range(4):
            chunk = jnp.asarray(pts[t * 48:(t + 1) * 48])
            key = jax.random.fold_in(jax.random.PRNGKey(5), t)
            fused, front_f, _ = tick(fused, chunk, jnp.ones(48, bool),
                                     key, jnp.bool_(t > 0))
            if t:
                plain, _ = win.advance_epoch(plain)
            plain, _ = ins(plain, chunk, jnp.ones(48, bool), key)
            front_p = win.finalize(plain, cfg=cfg)
            np.testing.assert_array_equal(np.asarray(front_f.points),
                                          np.asarray(front_p.points))
            np.testing.assert_array_equal(np.asarray(front_f.mask),
                                          np.asarray(front_p.mask))
            assert int(front_f.count) == int(front_p.count)
            assert bool(front_f.overflow) == bool(front_p.overflow)
        assert not bool(front_f.overflow)
        # the reduced-rows ring holds the same answer as full capacity
        np.testing.assert_array_equal(
            np.asarray(win.finalize(fused, cfg=cfg).points),
            np.asarray(front_p.points))


def test_windowed_1d_mesh_single_device():
    from repro.launch.mesh import make_worker_mesh
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    pts = _dataset(7, n=128)
    ops = [("insert", pts[:64]), ("advance",), ("insert", pts[64:128]),
           ("advance",), ("insert", pts[128:])]
    _assert_window_equals_oneshot(cfg, 2, ops,
                                  mesh=make_worker_mesh(1))


def test_batched_window_equals_per_window():
    """The batched windowed insert (Q rings, shared clock, one dispatch)
    is bitwise the per-window path."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=256, block=64,
                    bucket_factor=6.0)
    q, n, d = 3, 96, 4
    waves = [[generate("uniform", jax.random.PRNGKey(10 * w + i), n, d)
              for i in range(q)] for w in range(3)]
    keys = [jax.random.split(jax.random.PRNGKey(50 + w), q)
            for w in range(3)]
    bstate = win.init_window_state(cfg, d, epochs=2, q=q)
    bins = win.insert_window_batch_fn(cfg)
    states = [win.init_window_state(cfg, d, epochs=2) for _ in range(q)]
    ins = win.insert_window_fn(cfg)
    for w, wave in enumerate(waves):
        bstate, _ = bins(bstate, jnp.stack(wave), jnp.ones((q, n), bool),
                         keys[w])
        for i in range(q):
            states[i], _ = ins(states[i], wave[i], jnp.ones(n, bool),
                               keys[w][i])
        if w < 2:
            bstate, _ = win.advance_epoch(bstate)
            states = [win.advance_epoch(s)[0] for s in states]
    outs = win.finalize(bstate, cfg=cfg)
    for i in range(q):
        ref = win.finalize(states[i], cfg=cfg)
        np.testing.assert_array_equal(np.asarray(outs.points[i]),
                                      np.asarray(ref.points))
        assert int(outs.count[i]) == int(ref.count)


def test_windowed_2d_mesh_8dev():
    """On a real (2 x 4) queries x workers mesh: sharded windowed feeds
    + ticks are bitwise equal to the vmap engine AND to one-shot
    recompute over the unexpired tuples."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SkyConfig
        from repro.core.datagen import generate
        from repro.launch.mesh import make_engine_mesh
        from repro.serve.engine import SkylineEngine
        assert len(jax.devices()) == 8
        cfg = SkyConfig(strategy="sliced", p=8, capacity=1024, block=64,
                        bucket_factor=4.0)
        data = [generate("anticorrelated", jax.random.PRNGKey(i), 1500, 4)
                for i in range(2)]
        cuts = [0, 500, 900, 1500]

        plain = SkylineEngine(cfg, min_n_bucket=64)
        sharded = SkylineEngine(cfg, min_n_bucket=64,
                                mesh=make_engine_mesh(2, 4),
                                shard_threshold_n=64)
        streams = [e.open_stream(4, q=2, key=jax.random.PRNGKey(77),
                                 window_epochs=2)
                   for e in (plain, sharded)]
        for i in range(3):
            for s in streams:
                s.feed([d[cuts[i]:cuts[i + 1]] for d in data])
                if i < 2:
                    s.tick()
        # ring of 2: wave 0 expired, waves 1+2 live
        assert sharded.sharded_dispatched == 3
        snap_p, snap_s = [s.snapshot() for s in streams]
        ref = plain.run([d[500:] for d in data])
        for bp, bs, (br, _) in zip(snap_p, snap_s, ref):
            np.testing.assert_array_equal(np.asarray(bp.points),
                                          np.asarray(bs.points))
            np.testing.assert_array_equal(np.asarray(bs.points),
                                          np.asarray(br.points))
            np.testing.assert_array_equal(np.asarray(bs.mask),
                                          np.asarray(br.mask))
            assert int(bp.count) == int(bs.count) == int(br.count)
        print("OK")
    """)
    assert "OK" in out


def test_all_expired_state_no_nan_scores():
    """Regression (count==0 guard): an all-expired window finalizes
    empty with finite buffers under every strategy — including the
    representative-filtering and NoSeq paths — and
    `select_representatives` never leaks non-sentinel rows for masked
    selections."""
    for cfg in (
        SkyConfig(strategy="sliced", p=4, capacity=256, block=64,
                  bucket_factor=6.0),
        SkyConfig(strategy="grid", p=16, capacity=256, block=64,
                  bucket_factor=8.0, rep_filter="region", noseq=True),
    ):
        state = win.init_window_state(cfg, 4, epochs=2)
        ins = win.insert_window_fn(cfg)
        state, _ = ins(state, generate("uniform", jax.random.PRNGKey(0),
                                       64, 4), jnp.ones(64, bool),
                       jax.random.PRNGKey(1))
        state, _ = win.expire_epoch(state)
        out = win.finalize(state, cfg=cfg)
        assert int(out.count) == 0 and not bool(out.mask.any())
        assert not bool(jnp.any(jnp.isnan(out.points)))
        # the emptied state still absorbs inserts through the rep-filter
        # path (empty partitions select no representatives)
        state, _ = ins(state, generate("uniform", jax.random.PRNGKey(2),
                                       64, 4), jnp.ones(64, bool),
                       jax.random.PRNGKey(3))
        out = win.finalize(state, cfg=cfg)
        assert int(out.count) > 0
        assert not bool(jnp.any(jnp.isnan(out.points)))


def test_select_representatives_empty_inputs_sentinel_filled():
    """Masked/empty selections return sentinel-filled rows (the repo
    invalid-row convention), never arbitrary point data or NaNs."""
    for n in (0, 8):
        pts = jnp.asarray(np.arange(n * 4, dtype=np.float32).reshape(n, 4))
        mask = jnp.zeros((n,), bool)
        for strat in ("sorted", "region", "random"):
            reps, rm = select_representatives(
                pts, mask, 4, strategy=strat, key=jax.random.PRNGKey(0))
            assert not bool(rm.any())
            assert not bool(jnp.any(jnp.isnan(reps)))
            if n:
                np.testing.assert_array_equal(
                    np.asarray(reps), np.full_like(np.asarray(reps),
                                                   SENTINEL))
    # partially masked: the masked filler rows are sentinel too
    pts = jnp.asarray(np.random.default_rng(0).random((6, 3)), jnp.float32)
    mask = jnp.asarray([True, True, False, False, False, False])
    reps, rm = select_representatives(pts, mask, 4, strategy="sorted")
    assert np.asarray(reps)[~np.asarray(rm)].flatten().tolist() == \
        [float(SENTINEL)] * int((~rm).sum()) * 3
