"""block-SFS and the skyline buffers vs the O(N^2) oracle, including
hypothesis property tests over distributions, duplicates, and masks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import block_sfs, compact, naive_skyline_mask, skyline
from repro.core.datagen import generate


def _as_set(pts, mask):
    return set(map(tuple, np.asarray(pts)[np.asarray(mask)]))


@pytest.mark.parametrize("dist", ["uniform", "correlated", "anticorrelated"])
@pytest.mark.parametrize("n,d", [(100, 2), (500, 4), (257, 7)])
def test_block_sfs_matches_oracle(dist, n, d):
    pts = generate(dist, jax.random.PRNGKey(n + d), n, d)
    want = _as_set(pts, naive_skyline_mask(pts))
    sky = block_sfs(pts, capacity=n, block=64)
    assert _as_set(sky.points, sky.mask) == want
    assert int(sky.count) == len(want)
    assert not bool(sky.overflow)


def test_block_sfs_respects_mask():
    pts = jnp.array([[0.0, 0.0], [0.5, 0.5], [0.6, 0.4]], jnp.float32)
    mask = jnp.array([False, True, True])  # exclude the dominator
    sky = block_sfs(pts, mask, capacity=4, block=2)
    assert _as_set(sky.points, sky.mask) == _as_set(pts, mask)


def test_duplicates_all_kept():
    # equal tuples do not dominate each other (strict < required)
    pts = jnp.array([[0.3, 0.7]] * 5 + [[0.8, 0.9]], jnp.float32)
    sky = block_sfs(pts, capacity=8, block=4)
    assert int(sky.count) == 5
    mask = naive_skyline_mask(pts)
    assert int(mask.sum()) == 5


def test_overflow_flag_and_subset_guarantee():
    pts = generate("anticorrelated", jax.random.PRNGKey(0), 400, 5)
    full = block_sfs(pts, capacity=400, block=64)
    small_cap = max(int(full.count) // 3, 1)
    sky = block_sfs(pts, capacity=small_cap, block=64)
    assert bool(sky.overflow)
    # never a spurious member: result is a subset of the true skyline
    assert _as_set(sky.points, sky.mask) <= _as_set(full.points, full.mask)


def test_skyline_empty_input_returns_wellformed_buffer():
    """Regression: n == 0 used to derive capacity=0 and push a zero-row
    window through block_sfs; it must return an empty SkyBuffer."""
    pts = jnp.zeros((0, 3), jnp.float32)
    buf = skyline(pts)
    assert buf.points.shape[1] == 3
    assert buf.points.shape[0] >= 1
    assert int(buf.count) == 0
    assert not bool(buf.overflow)
    assert not bool(buf.mask.any())


def test_skyline_all_masked_input():
    pts = generate("uniform", jax.random.PRNGKey(1), 32, 4)
    buf = skyline(pts, jnp.zeros((32,), jnp.bool_))
    assert int(buf.count) == 0
    assert not bool(buf.overflow)
    assert not bool(buf.mask.any())


def test_compact():
    pts = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    mask = jnp.array([True, False, True, False, True, False])
    buf = compact(pts, mask, 4)
    assert int(buf.count) == 3
    got = np.asarray(buf.points)[np.asarray(buf.mask)]
    np.testing.assert_array_equal(got, np.asarray(pts)[::2])


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 200), st.integers(2, 7), st.integers(0, 3),
       st.integers(0, 2 ** 31 - 1))
def test_hypothesis_sfs_oracle(n, d, quant, seed):
    """Random data with heavy ties (quantized) across sizes/dims."""
    rng = np.random.default_rng(seed)
    levels = [3, 5, 17, 0][quant]
    if levels:
        pts = jnp.asarray(rng.integers(0, levels, (n, d)) / levels,
                          jnp.float32)
    else:
        pts = jnp.asarray(rng.random((n, d)), jnp.float32)
    mask = jnp.asarray(rng.random(n) > 0.2)
    want = _as_set(pts, naive_skyline_mask(pts, mask))
    sky = block_sfs(pts, mask, capacity=n, block=32)
    assert _as_set(sky.points, sky.mask) == want
    assert not bool(sky.overflow)


def test_skyline_api():
    pts = generate("uniform", jax.random.PRNGKey(7), 300, 3)
    sky = skyline(pts)
    want = _as_set(pts, naive_skyline_mask(pts))
    assert _as_set(sky.points, sky.mask) == want
