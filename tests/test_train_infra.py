"""Training infrastructure: microbatch equivalence, AdamW reference check,
clipping, int8 compression error feedback, checkpoint roundtrip/resume,
ZeRO-1 spec derivation."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager, latest_step, restore, save
from repro.configs import get_config
from repro.data.pipeline import DataState, make_batch, next_batch
from repro.models import transformer as T
from repro.models.common import init_params
from repro.train.optim import (OptConfig, adamw_init, adamw_update,
                               clip_by_global_norm, cosine_lr,
                               dequantize_int8, quantize_int8)
from repro.train.step import init_state, make_train_step


def _setup(arch="mamba2-780m", micro=1, f32=False):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              microbatches=micro,
                              **({"compute_dtype": "float32"} if f32
                                 else {}))
    params = init_params(T.lm_plan(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_microbatch_equivalence():
    """k=1 vs k=4 accumulation: same loss, near-identical update."""
    opt = OptConfig(total_steps=10, warmup_steps=1)
    batch = make_batch(get_config("mamba2-780m", smoke=True), 8, 32,
                       DataState(0, 0))
    outs = {}
    for k in (1, 4):
        cfg, params = _setup(micro=k, f32=True)  # f32: exact accumulation
        state = init_state(params, opt)
        state, metrics = jax.jit(make_train_step(cfg, opt))(state, batch)
        outs[k] = (float(metrics["ce_loss"]),
                   jax.tree.leaves(state["params"]))
    assert abs(outs[1][0] - outs[4][0]) < 1e-3
    for a, b in zip(outs[1][1], outs[4][1]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)


def test_adamw_matches_numpy_reference():
    opt = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10 ** 9, b1=0.9,
                    b2=0.999, eps=1e-8, weight_decay=0.01, clip_norm=1e9)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]], jnp.float32)}
    state = adamw_init(p, opt)
    new_p, _, _ = adamw_update(g, state, p, opt)
    # numpy reference (step 1, cosine at step 1 ~ lr)
    lr = float(cosine_lr(jnp.int32(1), opt))
    gn = np.asarray(g["w"])
    m = 0.1 * gn
    v = 0.001 * gn * gn
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.999)
    want = np.asarray(p["w"]) - lr * (mh / (np.sqrt(vh) + 1e-8)
                                      + 0.01 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(250.0)) < 1e-4
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert abs(total - 1.0) < 1e-5


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.01, 100.0))
def test_int8_quantization_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64) * scale, jnp.float32)
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s, jnp.float32)
    # error bounded by half a quantization bucket
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6


def test_int8_compression_tracks_uncompressed():
    """int8 + error feedback must track the uncompressed loss trajectory
    closely (the compression is unbiased in the long run) and keep the
    residual error buffer bounded."""
    trajectories = {}
    final_state = None
    for compress in (None, "int8"):
        opt = OptConfig(lr=1e-3, total_steps=30, warmup_steps=1,
                        compress=compress)
        cfg, params = _setup(f32=True)
        state = init_state(params, opt)
        step = jax.jit(make_train_step(cfg, opt))
        data = DataState(1, 0)
        losses = []
        for _ in range(10):
            batch, data = next_batch(cfg, 8, 32, data)
            state, metrics = step(state, batch)
            losses.append(float(metrics["ce_loss"]))
        trajectories[compress] = losses
        if compress == "int8":
            final_state = state
    dev = np.max(np.abs(np.asarray(trajectories[None])
                        - np.asarray(trajectories["int8"])))
    assert dev < 0.05, trajectories
    err_norm = max(float(jnp.max(jnp.abs(e)))
                   for e in jax.tree.leaves(final_state["opt"]["err"]))
    assert np.isfinite(err_norm)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg, params = _setup()
    opt = OptConfig()
    state = init_state(params, opt)
    d = str(tmp_path / "ck")
    save(d, 7, state, {"data_seed": 5, "data_step": 7})
    got, step, extra = restore(d, state)
    assert step == 7 and extra == {"data_seed": 5, "data_step": 7}
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    mgr = CheckpointManager(d, keep=2, async_save=False)
    for s in (8, 9, 10):
        mgr.save(s, state)
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                   if x.startswith("step_"))
    assert steps == [9, 10]


def test_train_resume_determinism(tmp_path):
    """20 straight steps == 10 steps + restart + 10 steps (same data
    cursor, same final params)."""
    from repro.launch.train import train_loop
    cfg, _ = _setup()
    opt = OptConfig(total_steps=20, warmup_steps=2)

    s_straight, _ = train_loop(cfg, steps=20, batch=4, seq=32,
                               ckpt_dir=None, opt_cfg=opt, log_every=100)
    d = str(tmp_path / "ck2")
    train_loop(cfg, steps=10, batch=4, seq=32, ckpt_dir=d, ckpt_every=10,
               opt_cfg=opt, log_every=100)
    s_resumed, _ = train_loop(cfg, steps=20, batch=4, seq=32, ckpt_dir=d,
                              ckpt_every=10, opt_cfg=opt, log_every=100)
    for a, b in zip(jax.tree.leaves(s_straight["params"]),
                    jax.tree.leaves(s_resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_zero1_specs_add_data_axis():
    from jax.sharding import PartitionSpec as P
    from repro.models.common import DEFAULT_RULES
    from repro.sharding import zero1_pspecs
    cfg = get_config("yi-6b")
    plan = T.lm_plan(cfg)
    specs = zero1_pspecs(plan, DEFAULT_RULES, 16)
    flat = {"/".join(str(p) for p in path): s
            for path, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}
    emb = [v for k, v in flat.items() if "embed" in k.lower()][0]
    assert "data" in str(emb)  # moments got an extra data-axis shard
