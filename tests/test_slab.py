"""Shared slab allocator (`repro.serve.slab` + the slab-backed
`SkylineStream`): thousands of tenant streams lease slots from ONE
device-resident arena per bucket — device buffers scale with the bucket
count, never the stream count — and slot promotion keeps results
bit-for-bit exact as tenant fronts grow."""

import jax
import numpy as np

from repro.core import SkyConfig, parallel
from repro.core.datagen import generate
from repro.serve.engine import SkylineEngine
from repro.serve.slab import SlabArena, slot_rows_bucket


def test_slot_rows_bucket():
    assert slot_rows_bucket(1, 64, 4096) == 64
    assert slot_rows_bucket(65, 64, 4096) == 128
    assert slot_rows_bucket(4097, 64, 4096) == 4096  # clipped at capacity
    assert slot_rows_bucket(1, 64, 32) == 32         # floor above cap


def test_arena_lease_release_reuse_blanked():
    arena = SlabArena(epochs=2, rows=8, d=3, init_slots=2)
    a = arena.lease(2)
    assert arena.leased == 2
    # dirty a slot, release, re-lease: contents come back blank
    leaves = list(arena.leaves())
    leaves[1] = leaves[1].at[a[0]].set(True)  # mask leaf
    leaves[2] = leaves[2].at[a[0]].set(5)     # count leaf
    arena.set_leaves(tuple(leaves))
    arena.release([a[0]])
    b = arena.lease(1)
    assert b == [a[0]]  # LIFO free list reuses the released slot
    assert not bool(arena.leaves()[1][b[0]].any())
    assert int(arena.leaves()[2][b[0]].sum()) == 0
    assert float(arena.leaves()[0][b[0]].min()) > 1e38  # sentinel-filled


def test_closed_stream_fails_fast():
    import pytest
    cfg = SkyConfig(strategy="sliced", p=4, capacity=128, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64)
    s = engine.open_stream(3, q=1)
    s.close()
    chunk = generate("uniform", jax.random.PRNGKey(0), 64, 3)
    for op in (lambda: s.feed([chunk]), s.snapshot, s.counters):
        with pytest.raises(ValueError, match="closed"):
            op()


def test_arena_double_release_rejected():
    """Releasing a slot twice (or a slot the arena never issued) raises
    instead of letting two tenants lease the same slot."""
    import pytest
    arena = SlabArena(epochs=1, rows=4, d=2, init_slots=4)
    a = arena.lease(2)
    arena.release([a[0]])
    with pytest.raises(ValueError):
        arena.release([a[0]])  # stale slot list
    with pytest.raises(ValueError):
        arena.release([99])    # never allocated
    assert arena.leased == 1   # accounting intact


def test_stream_accepts_typed_prng_keys():
    """open_stream takes both legacy uint32 keys and new-style typed
    keys (stored host-side as raw bits — idle streams hold no device
    buffers either way)."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=128, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64)
    chunk = generate("uniform", jax.random.PRNGKey(1), 64, 3)
    for key in (jax.random.PRNGKey(7), jax.random.key(7)):
        s = engine.open_stream(3, q=1, key=key)
        assert isinstance(s._key, np.ndarray)
        s.feed([chunk])
        (ref, _), = engine.run([chunk])
        np.testing.assert_array_equal(
            np.asarray(s.snapshot()[0].points), np.asarray(ref.points))


def test_arena_growth_doubles_and_keeps_content():
    arena = SlabArena(epochs=1, rows=4, d=2, init_slots=2)
    a = arena.lease(2)
    leaves = list(arena.leaves())
    leaves[2] = leaves[2].at[a[1]].set(7)
    arena.set_leaves(tuple(leaves))
    arena.lease(5)  # forces growth past 2 slots
    assert arena.capacity >= 7
    assert arena.grows >= 1
    assert int(arena.leaves()[2][a[1]].sum()) == 7  # content survived
    assert arena.num_buffers() == 6  # growth replaced, not accumulated


def test_thousand_idle_streams_one_arena_per_bucket():
    """The headline memory property: 1000 idle tenant streams of one
    bucket live in ONE arena — device buffers are O(#buckets), not
    O(#streams)."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=256, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64)
    # settle transient allocations before measuring
    warm = engine.open_stream(3, q=1, window_epochs=4)
    before = len(jax.live_arrays())
    streams = [engine.open_stream(3, q=1, window_epochs=4)
               for _ in range(1000)]
    after = len(jax.live_arrays())
    # one bucket => one arena => one fixed set of device leaves; the
    # 1000 streams only moved the host-side free list
    assert len(engine._arenas) == 1
    (key, report), = engine.arena_report().items()
    assert report["leased"] == 1001  # + the warmup stream
    assert report["slots"] >= 1001
    assert report["buffers"] == 6
    assert after - before < 32, (before, after)
    # closing returns every slot; the arena (and its buffers) remain
    for s in streams:
        s.close()
    assert engine.arena_report()[key]["leased"] == 1
    del warm  # keep it alive until here


def test_streams_share_arena_and_feed_is_exact():
    """Two independently opened streams of one bucket lease from the
    same arena; feeding one never perturbs the other, and both snapshot
    bit-for-bit to one-shot answers."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=256, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64)
    a = generate("anticorrelated", jax.random.PRNGKey(0), 200, 4)
    b = generate("uniform", jax.random.PRNGKey(1), 150, 4)
    s1 = engine.open_stream(4, q=1)
    s2 = engine.open_stream(4, q=1)
    assert s1.arena is s2.arena
    assert set(s1.slots).isdisjoint(s2.slots)
    s1.feed([a[:100]])
    s2.feed([b])
    s1.feed([a[100:]])
    (ra, _), (rb, _) = engine.run([a, b])
    np.testing.assert_array_equal(np.asarray(s1.snapshot()[0].points),
                                  np.asarray(ra.points))
    np.testing.assert_array_equal(np.asarray(s2.snapshot()[0].points),
                                  np.asarray(rb.points))


def test_promotion_grows_rows_bucket_and_stays_exact():
    """A tenant whose front outgrows its slot is promoted to the next
    rows bucket (new arena) with nothing lost — snapshots stay bitwise
    one-shot — and its old slots return to the free list."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_slab_rows=8)
    pts = generate("anticorrelated", jax.random.PRNGKey(3), 400, 4)
    stream = engine.open_stream(4, q=1)
    first_arena, first_rows = stream.arena, stream.rows
    assert first_rows == 8
    for lo in range(0, 400, 100):
        stream.feed([pts[lo:lo + 100]])
    stream.drain()  # settle the async overflow check -> promotion lands
    assert stream.rows > first_rows  # anticorrelated front > 8 rows
    assert first_arena.leased == 0   # old slots released on promotion
    (ref, _), = engine.run([pts])
    buf = stream.snapshot()[0]
    np.testing.assert_array_equal(np.asarray(buf.points),
                                  np.asarray(ref.points))
    np.testing.assert_array_equal(np.asarray(buf.mask),
                                  np.asarray(ref.mask))
    assert int(buf.count) == int(ref.count)
    # the slot tracks the *front* size, not the engine capacity
    assert stream.rows < 512


def test_windowed_promotion_carries_old_epochs():
    """Promotion in a windowed stream re-pads every epoch, not just the
    freshly inserted head — older epochs survive the move bitwise."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_slab_rows=8)
    pts = generate("anticorrelated", jax.random.PRNGKey(5), 300, 4)
    ws = engine.open_stream(4, q=1, window_epochs=3)
    ws.feed([pts[:100]])
    ws.tick()
    ws.feed([pts[100:300]])  # head front outgrows 8/16 rows -> promote
    (ref, _), = engine.run([pts[:300]])
    buf = ws.drain().snapshot()[0]  # settle the fits check -> promote
    assert ws.rows > 8
    np.testing.assert_array_equal(np.asarray(buf.points),
                                  np.asarray(ref.points))
    assert int(buf.count) == int(ref.count)


def test_feed_defers_fits_sync_until_next_operation():
    """NO stream op blocks on the overflow check: `feed` defers the
    per-slot fits read as a pending record, `snapshot` overlays the
    record INSIDE its jitted program (bitwise exact, no host resolve),
    and the promotion lands only at the explicit blocking settle
    (`drain`) or once a non-blocking poll finds the vector delivered."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_slab_rows=8)
    pts = generate("anticorrelated", jax.random.PRNGKey(9), 200, 4)
    stream = engine.open_stream(4, q=1)
    stream.feed([pts])          # front > 8 rows: pending, not promoted
    assert stream.rows == 8
    assert stream._pendings
    (ref, _), = engine.run([pts])
    buf = stream.snapshot()[0]  # overlay read; may promote only if the
    np.testing.assert_array_equal(  # async copy already delivered
        np.asarray(buf.points), np.asarray(ref.points))
    stream.drain()              # the sanctioned blocking settle
    assert not stream._pendings
    assert stream.rows > 8
    buf = stream.snapshot()[0]
    np.testing.assert_array_equal(np.asarray(buf.points),
                                  np.asarray(ref.points))


def test_epoch_capacity_caps_slots_and_stays_exact():
    """A windowed stream with a declared epoch_capacity keeps its slot
    ceiling at the rounded epoch capacity — promotions stop there, well
    below the engine's full state capacity — and snapshots stay bitwise
    one-shot."""
    import pytest
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_slab_rows=8)
    pts = generate("anticorrelated", jax.random.PRNGKey(11), 120, 4)
    ws = engine.open_stream(4, q=1, window_epochs=3, epoch_capacity=100)
    assert ws.cap == 128  # 100 rounded up to the 64-row dominance block
    ws.feed([pts[:60]])
    ws.tick()
    ws.feed([pts[60:]])
    (ref, _), = engine.run([pts])
    buf = ws.snapshot()[0]
    assert ws.rows <= ws.cap < 512
    np.testing.assert_array_equal(np.asarray(buf.points),
                                  np.asarray(ref.points))
    assert int(buf.count) == int(ref.count)
    # epoch_capacity is a windowed-stream contract
    with pytest.raises(ValueError, match="windowed"):
        engine.open_stream(4, q=1, epoch_capacity=100)


def test_all_idle_feed_and_all_expired_snapshot():
    """The pack path tolerates an all-idle feed (every chunk None) and
    an all-expired window: snapshots stay empty and finite — the
    count==0 regression at the engine level."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=256, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64)
    ws = engine.open_stream(4, q=2, window_epochs=2)
    ws.feed([None, None])  # nothing arrived anywhere
    for buf in ws.snapshot():
        assert int(buf.count) == 0 and not bool(buf.mask.any())
        assert not bool(np.isnan(np.asarray(buf.points)).any())
    ws.feed([generate("uniform", jax.random.PRNGKey(0), 64, 4), None])
    ws.expire_epoch()  # the only live epoch empties in place
    for buf in ws.snapshot():
        assert int(buf.count) == 0 and not bool(buf.mask.any())
        assert not bool(np.isnan(np.asarray(buf.points)).any())
    counters = ws.counters()
    assert counters["count"].tolist() == [0, 0]
    assert not counters["overflow"].any()


def test_slab_feed_programs_bounded_by_bucket():
    """Same-shape feeds across MANY streams share one compiled slab
    program per (rows, chunk-bucket) — traces never scale with the
    stream count or the ring position."""
    cfg = SkyConfig(strategy="sliced", p=4, capacity=128, block=64,
                    bucket_factor=6.0)
    engine = SkylineEngine(cfg, min_n_bucket=64, min_slab_rows=128)
    streams = [engine.open_stream(3, q=1, window_epochs=3)
               for _ in range(6)]
    before_feed = parallel.trace_count("slab_feed")
    before_tick = parallel.trace_count("slab_tick")
    before_snap = parallel.trace_count("slab_snapshot")
    for step in range(4):
        for j, s in enumerate(streams):
            s.feed([generate("uniform",
                             jax.random.PRNGKey(17 * step + j), 64, 3)])
            s.snapshot()
        for s in streams:
            s.tick()
    # one arena growth step may retrace each program once (the slot axis
    # is a shape); beyond that, everything is shared
    assert parallel.trace_count("slab_feed") - before_feed <= 2
    assert parallel.trace_count("slab_tick") - before_tick <= 2
    assert parallel.trace_count("slab_snapshot") - before_snap <= 2
