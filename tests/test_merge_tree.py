"""Tree merge == flat merge, bit for bit (`SkyConfig.merge`).

The ⌈log₂(W)⌉-round pruning ppermute tree is a different collective
*schedule* over the same canonical-order math, so its output must be
bitwise identical to the flat all_gather union everywhere the flat mode
runs: sequential and NoSeq branches, tie/duplicate-heavy data, the
in-process degenerate mesh, a real 8-device workers mesh, a non-power-
of-two 6-device mesh (reduce-to-root handles any W), and through the
incremental chunk-insert reduce. Also pins the dispatch discipline: one
compiled tree program serves every same-shape chunk (no per-round or
per-call retrace)."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import SkyConfig, parallel, parallel_skyline
from repro.core import incremental as inc
from repro.core.datagen import generate
from repro.core.parallel import merge_rounds, resolve_merge

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _tie_heavy(seed: int, n: int, d: int, quant: int) -> jnp.ndarray:
    """Anticorrelated data quantized onto a coarse lattice: score ties
    and exact cross-partition duplicates, the cases where only the
    shared canonical total order keeps the two schedules bit-equal."""
    pts = generate("anticorrelated", jax.random.PRNGKey(seed), n, d)
    return jnp.round(pts * quant) / quant


def _assert_bitwise_equal(base: SkyConfig, pts, *, mesh=None):
    bufs = {}
    for merge in ("flat", "tree"):
        cfg = dataclasses.replace(base, merge=merge)
        bufs[merge], _ = parallel_skyline(pts, cfg=cfg, mesh=mesh)
    f, t = bufs["flat"], bufs["tree"]
    np.testing.assert_array_equal(np.asarray(f.points),
                                  np.asarray(t.points))
    np.testing.assert_array_equal(np.asarray(f.mask), np.asarray(t.mask))
    assert int(f.count) == int(t.count)
    assert bool(f.overflow) == bool(t.overflow)
    return f


# --------------------------------------------------------------------------
# resolve_merge: the single topology decision point
# --------------------------------------------------------------------------

def test_merge_rounds_is_ceil_log2():
    assert [merge_rounds(w) for w in (1, 2, 3, 4, 5, 8, 9, 512)] == \
        [0, 1, 2, 2, 3, 3, 4, 9]


def test_resolve_merge_modes_and_auto():
    flat = SkyConfig(merge="flat")
    tree = SkyConfig(merge="tree")
    auto = SkyConfig(merge="auto", capacity=1024)
    assert resolve_merge(flat, axis_size=8) == "flat"
    assert resolve_merge(tree, axis_size=8) == "tree"
    # no workers axis: the union is device-local, auto stays flat
    assert resolve_merge(auto, axis_size=None) == "flat"
    assert resolve_merge(auto, axis_size=1, p_total=8, local_cap=4096,
                         d=4) == "flat"
    # large union vs small capacity: the tree's modeled boundary wins
    assert resolve_merge(auto, axis_size=8, p_total=64, local_cap=4096,
                         d=4) == "tree"
    # tiny union: one gather is cheaper than log2(W)+2 capacity rounds
    assert resolve_merge(auto, axis_size=8, p_total=8, local_cap=64,
                         d=4) == "flat"
    try:
        resolve_merge(SkyConfig(merge="bogus"))
    except ValueError as e:
        assert "bogus" in str(e)
    else:
        raise AssertionError("bad merge mode must raise")


# --------------------------------------------------------------------------
# property: tree == flat bitwise on the in-process mesh (any strategy,
# both branches, tie/duplicate-heavy)
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 2 ** 16), n=st.integers(96, 420),
       quant=st.sampled_from([5, 9, 16]),
       strategy=st.sampled_from(["random", "sliced", "grid", "angular"]),
       noseq=st.booleans())
@settings(max_examples=12, deadline=None)
def test_tree_equals_flat_property(seed, n, quant, strategy, noseq):
    from repro.launch.mesh import make_worker_mesh
    pts = _tie_heavy(seed, n, 3, quant)
    base = SkyConfig(strategy=strategy, p=4, capacity=512, block=64,
                     bucket_factor=10.0, noseq=noseq)
    _assert_bitwise_equal(base, pts, mesh=make_worker_mesh())


# --------------------------------------------------------------------------
# real meshes (subprocess: the main process keeps one device)
# --------------------------------------------------------------------------

def test_tree_equals_flat_8_devices_all_strategies():
    out = _run("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SkyConfig, parallel_skyline, \\
            skyline_mask_exact
        from repro.core.datagen import generate
        from repro.launch.mesh import make_worker_mesh
        assert len(jax.devices()) == 8
        mesh = make_worker_mesh()
        pts = generate("anticorrelated", jax.random.PRNGKey(3), 1200, 4)
        pts = jnp.round(pts * 12) / 12  # ties + duplicates
        want = set(map(tuple, np.asarray(pts)[np.asarray(
            skyline_mask_exact(pts))]))
        for strat in ["random", "sliced", "grid", "angular"]:
            for noseq in [False, True]:
                base = SkyConfig(strategy=strat, p=16, capacity=2048,
                                 block=64, bucket_factor=10.0,
                                 rep_filter="sorted", noseq=noseq)
                bufs = {}
                for merge in ["flat", "tree"]:
                    cfg = dataclasses.replace(base, merge=merge)
                    bufs[merge], _ = parallel_skyline(pts, cfg=cfg,
                                                      mesh=mesh)
                    got = set(map(tuple, np.asarray(bufs[merge].points)[
                        np.asarray(bufs[merge].mask)]))
                    assert got == want, (strat, noseq, merge)
                f, t = bufs["flat"], bufs["tree"]
                np.testing.assert_array_equal(np.asarray(f.points),
                                              np.asarray(t.points))
                np.testing.assert_array_equal(np.asarray(f.mask),
                                              np.asarray(t.mask))
                assert int(f.count) == int(t.count), (strat, noseq)
                assert bool(f.overflow) == bool(t.overflow)
        print("OK")
    """)
    assert "OK" in out


def test_tree_equals_flat_non_power_of_two_workers():
    """W=6: the reduce-to-root schedule must stay exact when the last
    round's partner is missing (grid is excluded — it rounds p to g^d,
    which 6 need not divide)."""
    out = _run("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SkyConfig, parallel_skyline
        from repro.core import incremental as inc
        from repro.core.datagen import generate
        from repro.launch.mesh import make_worker_mesh
        nd = len(jax.devices())
        assert nd == 6
        mesh = make_worker_mesh()
        pts = generate("anticorrelated", jax.random.PRNGKey(7), 1080, 3)
        pts = jnp.round(pts * 9) / 9
        for strat in ["sliced", "random"]:
            for noseq in [False, True]:
                base = SkyConfig(strategy=strat, p=2 * nd, capacity=1024,
                                 block=64, bucket_factor=10.0,
                                 noseq=noseq)
                bufs = {}
                for merge in ["flat", "tree"]:
                    cfg = dataclasses.replace(base, merge=merge)
                    bufs[merge], _ = parallel_skyline(pts, cfg=cfg,
                                                      mesh=mesh)
                f, t = bufs["flat"], bufs["tree"]
                np.testing.assert_array_equal(np.asarray(f.points),
                                              np.asarray(t.points))
                np.testing.assert_array_equal(np.asarray(f.mask),
                                              np.asarray(t.mask))
                assert int(f.count) == int(t.count), (strat, noseq)
                assert bool(f.overflow) == bool(t.overflow)
        # the chunk-insert reduce under tree mode: chunking-invariant
        cfg = SkyConfig(strategy="sliced", p=2 * nd, capacity=1024,
                        block=64, bucket_factor=10.0, merge="tree")
        one, _ = parallel_skyline(pts, cfg=cfg, mesh=mesh)
        state = inc.init_state(cfg, pts.shape[1])
        for lo in range(0, pts.shape[0], 360):
            state, _ = inc.insert_chunk(state, pts[lo:lo + 360],
                                        cfg=cfg, mesh=mesh)
        fin = inc.finalize(state, cfg=cfg)
        op = np.asarray(one.points)[np.asarray(one.mask)]
        fp = np.asarray(fin.points)[np.asarray(fin.mask)]
        assert op.shape == fp.shape and np.array_equal(op, fp)
        print("OK")
    """, devices=6)
    assert "OK" in out


# --------------------------------------------------------------------------
# dispatch discipline: one compiled tree program serves all rounds and
# every same-shape chunk
# --------------------------------------------------------------------------

def test_tree_chunk_inserts_trace_once():
    from repro.launch.mesh import make_worker_mesh
    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=10.0, merge="tree")
    mesh = make_worker_mesh()
    pts = _tie_heavy(11, 480, 3, 9)
    state = inc.init_state(cfg, pts.shape[1])
    before = parallel.trace_count("insert")
    for lo in range(0, 480, 120):  # 4 same-shape chunks
        state, _ = inc.insert_chunk(state, pts[lo:lo + 120], cfg=cfg,
                                    mesh=mesh)
    assert parallel.trace_count("insert") - before == 1, \
        "the log2(W)-round tree must live inside the one cached insert " \
        "program, not retrace per chunk"
    # and the result is still the dataset's skyline, bit-equal to flat
    fin = inc.finalize(state, cfg=cfg)
    ref, _ = parallel_skyline(pts, cfg=dataclasses.replace(
        cfg, merge="flat"), mesh=mesh)
    op = np.asarray(ref.points)[np.asarray(ref.mask)]
    fp = np.asarray(fin.points)[np.asarray(fin.mask)]
    assert op.shape == fp.shape and np.array_equal(op, fp)
