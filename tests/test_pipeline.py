"""GPipe pipeline parallelism == sequential layer application."""

import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_gpipe_matches_sequential():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.pipeline import gpipe_forward, pipeline_stages

        S, L, M, B, D = 4, 8, 6, 2, 16
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (L, D, D)) * (D ** -0.5)
        xs = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))

        def layer(wi, x):
            return jnp.tanh(x @ wi)

        def stage_fn(wstage, x):   # wstage: (L/S, D, D)
            def body(x, wi):
                return layer(wi, x), None
            y, _ = jax.lax.scan(body, x, wstage)
            return y

        # sequential reference
        def seq(x):
            for i in range(L):
                x = layer(w[i], x)
            return x
        want = jax.vmap(seq)(xs.reshape(M * B, D)).reshape(M, B, D)

        from repro.compat import make_mesh, shard_map
        mesh = make_mesh((S,), ("stage",))
        wst = pipeline_stages(w, S)
        got = jax.jit(shard_map(
            lambda ws, xs: gpipe_forward(stage_fn, ws, xs),
            mesh=mesh,
            in_specs=(P("stage"), P()), out_specs=P(),
            check_vma=False))(wst, xs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "OK" in r.stdout
