"""Partitioning strategies: Proposition 1 (partition-local-merge identity),
bucketize integrity, balance properties, grid/angular index validity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import naive_skyline_mask
from repro.core.datagen import generate
from repro.core.parallel import SkyConfig, effective_parts, parallel_skyline
from repro.core.partition import (angular_part_ids, bucketize,
                                  grid_cell_coords, grid_part_ids,
                                  random_part_ids, sliced_part_ids)

STRATEGIES = ["random", "sliced", "grid", "angular"]


def _sky_set(pts, mask=None):
    return set(map(tuple, np.asarray(pts)[np.asarray(
        naive_skyline_mask(pts, mask))]))


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("dist", ["uniform", "anticorrelated"])
def test_proposition1_identity(strategy, dist):
    """SKY(r) == SKY(SKY(r_1) u ... u SKY(r_p)) for every strategy."""
    pts = generate(dist, jax.random.PRNGKey(1), 500, 4)
    cfg = SkyConfig(strategy=strategy, p=8, capacity=1024, block=64,
                    bucket_factor=8.0)
    buf, stats = parallel_skyline(pts, cfg=cfg)
    assert not bool(buf.overflow), stats
    got = set(map(tuple, np.asarray(buf.points)[np.asarray(buf.mask)]))
    assert got == _sky_set(pts)


def test_bucketize_routes_every_valid_tuple_once():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.random((200, 3)), jnp.float32)
    mask = jnp.asarray(rng.random(200) > 0.3)
    ids = jnp.asarray(rng.integers(0, 7, 200), jnp.int32)
    b = bucketize(pts, mask, ids, 7, capacity=200)
    assert not bool(b.overflow)
    # per-partition contents match
    for p in range(7):
        want = {tuple(r) for r in np.asarray(pts)[
            np.asarray(mask) & (np.asarray(ids) == p)]}
        got = {tuple(r) for r in np.asarray(b.points[p])[
            np.asarray(b.mask[p])]}
        assert got == want
        assert int(b.counts[p]) == len(want)


def test_bucketize_overflow_detection():
    pts = jnp.zeros((50, 2), jnp.float32)
    ids = jnp.zeros((50,), jnp.int32)
    b = bucketize(pts, jnp.ones(50, bool), ids, 4, capacity=10)
    assert bool(b.overflow)
    assert int(b.counts[0]) == 50


def test_random_and_sliced_balance():
    n, p = 1000, 8
    ids = random_part_ids(jax.random.PRNGKey(0), n, p)
    counts = np.bincount(np.asarray(ids), minlength=p)
    assert counts.max() - counts.min() <= 1
    pts = generate("uniform", jax.random.PRNGKey(1), n, 3)
    ids = sliced_part_ids(pts, jnp.ones(n, bool), p)
    counts = np.bincount(np.asarray(ids), minlength=p)
    assert counts.max() - counts.min() <= 1


def test_sliced_is_sorted_runs():
    pts = generate("uniform", jax.random.PRNGKey(2), 300, 2)
    ids = np.asarray(sliced_part_ids(pts, jnp.ones(300, bool), 4))
    x = np.asarray(pts[:, 0])
    for lo in range(3):
        assert x[ids == lo].max() <= x[ids == lo + 1].min() + 1e-7


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 300), st.integers(2, 6), st.integers(2, 4),
       st.integers(0, 2 ** 31 - 1))
def test_grid_angular_ids_in_range(n, d, m, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.random((n, d)), jnp.float32)
    gid = np.asarray(grid_part_ids(pts, m))
    assert gid.min() >= 0 and gid.max() < m ** d
    aid = np.asarray(angular_part_ids(pts, m))
    assert aid.min() >= 0 and aid.max() < m ** (d - 1)
    coords = np.asarray(grid_cell_coords(pts, m))
    assert (coords >= 0).all() and (coords < m).all()


def test_grid_dominance_cell_consistency():
    """t dominates s => cell(t) <= cell(s) coordinate-wise."""
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.random((200, 3)), jnp.float32)
    coords = np.asarray(grid_cell_coords(pts, 4))
    from repro.kernels.dominance import dominance_matrix_ref
    dom = np.asarray(dominance_matrix_ref(pts, pts))
    js, is_ = np.nonzero(dom)
    assert (coords[js] <= coords[is_]).all()


def test_effective_parts():
    cfg = SkyConfig(strategy="grid", p=16)
    assert effective_parts(cfg, 4) == (16, 2)
    cfg = SkyConfig(strategy="angular", p=25)
    assert effective_parts(cfg, 3) == (25, 5)
    cfg = SkyConfig(strategy="sliced", p=12)
    assert effective_parts(cfg, 5) == (12, 0)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(STRATEGIES), st.integers(20, 250),
       st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_hypothesis_prop1_all_strategies(strategy, n, d, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.integers(0, 10, (n, d)) / 10.0, jnp.float32)
    cfg = SkyConfig(strategy=strategy, p=4, capacity=max(n, 16), block=32,
                    bucket_factor=float(n), rep_filter=None)
    buf, _ = parallel_skyline(pts, cfg=cfg)
    assert not bool(buf.overflow)
    got = set(map(tuple, np.asarray(buf.points)[np.asarray(buf.mask)]))
    assert got == _sky_set(pts)
