"""Per-arch smoke tests (assignment requirement): reduced config of each
family, one forward/train step on CPU, asserting output shapes + no NaNs,
plus prefill/decode consistency per family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, applicable_shapes, get_config, skip_reason
from repro.data.pipeline import DataState, make_batch
from repro.models import transformer as T
from repro.models.common import init_params
from repro.train.optim import OptConfig
from repro.train.step import init_state, make_train_step


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(T.lm_plan(cfg), jax.random.PRNGKey(0))
    b, s = 4, 32
    batch = make_batch(cfg, b, s, DataState(0, 0))

    logits, _, _ = jax.jit(lambda p, x: T.forward(p, cfg, x))(params, batch)
    # vlm batches carry (prefix image) + (s - prefix text) -> s positions
    assert logits.shape == (b, s, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()

    opt = OptConfig(total_steps=10, warmup_steps=1)
    cfg2 = dataclasses.replace(cfg, microbatches=2)
    state = init_state(params, opt)
    step = jax.jit(make_train_step(cfg2, opt))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["step"]) == 1
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b_.astype(jnp.float32))))
                for a, b_ in zip(jax.tree.leaves(state["params"]),
                                 jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if get_config(a).family != "encoder"])
def test_smoke_prefill_decode_consistency(arch):
    # The check compares two compilations of the same math (full forward
    # vs prefill/decode), so it must remove the two things that make
    # their outputs legitimately differ: bf16 compute (kernel-selection
    # wobble alone eats most of the tolerance) and MoE capacity drops —
    # WHICH token drops depends on every other token's routing, and the
    # decode step competes against 1 token where the full pass competes
    # against all 50, so near the capacity boundary the paths disagree
    # by O(1) on a few logits. f32 + drop-free capacity make the paths
    # bit-comparable; bf16 and dropping are still covered by the
    # forward/train smoke above.
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, remat=False, compute_dtype="float32",
                              capacity_factor=float(max(cfg.n_experts, 1)))
    params = init_params(T.lm_plan(cfg), jax.random.PRNGKey(0))
    b, s = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                              cfg.vocab)
    if cfg.family == "vlm":
        img = jax.random.normal(jax.random.PRNGKey(2),
                                (b, cfg.prefix_len, cfg.frontend_dim))
        full_in = {"image_emb": img, "tokens": toks}
        pre_in = {"image_emb": img, "tokens": toks[:, :s]}
        total = cfg.prefix_len + s + 1
    else:
        full_in = {"tokens": toks}
        pre_in = {"tokens": toks[:, :s]}
        total = s + 1
    logits_full, _, _ = jax.jit(lambda p, i: T.forward(p, cfg, i))(
        params, full_in)
    caches, last = jax.jit(lambda p, i: T.prefill(p, cfg, i, 64))(
        params, pre_in)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, -2]),
                               rtol=3e-2, atol=3e-2)
    _, dec = jax.jit(lambda p, c, t: T.decode_step(
        p, cfg, c, t, jnp.int32(total - 1)))(params, caches,
                                             toks[:, s:s + 1])
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=4e-2, atol=4e-2)


def test_shape_skips_documented():
    skips = {a: [s for s in ("train_4k", "prefill_32k", "decode_32k",
                             "long_500k")
                 if skip_reason(get_config(a), s)] for a in ARCH_NAMES}
    # encoder skips decode shapes; pure full-attention archs skip long_500k
    assert skips["hubert-xlarge"] == ["decode_32k", "long_500k"]
    for a in ["yi-6b", "qwen3-14b", "phi4-mini-3.8b", "starcoder2-7b",
              "paligemma-3b"]:
        assert skips[a] == ["long_500k"]
    for a in ["zamba2-1.2b", "llama4-maverick-400b-a17b", "mixtral-8x7b",
              "mamba2-780m"]:
        assert skips[a] == []
    total_cells = sum(len(applicable_shapes(get_config(a)))
                      for a in ARCH_NAMES)
    assert total_cells == 33  # 40 minus 7 documented skips
