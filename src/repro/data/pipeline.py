"""Input pipeline: deterministic synthetic corpus with a checkpointable
cursor — restart-safe (the cursor is saved with the training state) and
shardable (each data shard derives its stream from (seed, shard_id, step)).

Batches are {"tokens": (B, S) int32, "labels": (B, S) int32} with labels
pre-shifted; family-specific inputs for encoder (frames) and vlm
(image_emb) stubs. Label -1 = masked out of the loss.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = ["DataState", "make_batch", "next_batch"]


@dataclasses.dataclass(frozen=True)
class DataState:
    seed: int
    step: int

    def advance(self):
        return DataState(self.seed, self.step + 1)


def _tokens(rng: np.random.Generator, b: int, s: int, vocab: int):
    """Markov-ish synthetic text: a random walk over token ids with
    occasional jumps, so the LM has learnable local structure."""
    base = rng.integers(0, vocab, size=(b, 1))
    steps = rng.integers(-8, 9, size=(b, s))
    jumps = rng.random((b, s)) < 0.05
    steps = np.where(jumps, rng.integers(0, vocab, size=(b, s)), steps)
    toks = (np.cumsum(np.concatenate([base, steps[:, :-1]], 1), 1)
            % vocab).astype(np.int32)
    return toks


def make_batch(cfg, b: int, s: int, state: DataState, shard_id: int = 0):
    rng = np.random.default_rng(
        np.random.SeedSequence([state.seed, shard_id, state.step]))
    if cfg.family == "encoder":
        frames = rng.standard_normal((b, s, cfg.frontend_dim),
                                     dtype=np.float32)
        labels = rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)
        return {"frames": jnp.asarray(frames), "labels": jnp.asarray(labels)}
    if cfg.family == "vlm":
        s_text = s - cfg.prefix_len
        img = rng.standard_normal((b, cfg.prefix_len, cfg.frontend_dim),
                                  dtype=np.float32)
        toks = _tokens(rng, b, s_text + 1, cfg.vocab)
        return {"image_emb": jnp.asarray(img),
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}
    toks = _tokens(rng, b, s + 1, cfg.vocab)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def next_batch(cfg, b: int, s: int, state: DataState, shard_id: int = 0):
    return make_batch(cfg, b, s, state, shard_id), state.advance()
