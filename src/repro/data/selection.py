"""Skyline-based data curation — the paper's technique as a first-class
framework feature (DESIGN.md §4).

Each training example gets a criteria vector (smaller = better on every
axis, e.g. [-loss (we *want* hard examples -> negate), redundancy,
staleness]). The Pareto front (= skyline) is the set of examples that are
not dominated on all criteria simultaneously — a principled multi-criteria
alternative to single-score heuristics for hard-example mining and
data pruning. The selection runs through the same parallel pipeline
(partition → local skyline → merge/NoSeq) as the standalone library, so at
cluster scale the curation is distributed exactly like the paper's
computation.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import SkyConfig, parallel_skyline, skyline_mask

__all__ = ["pareto_mask", "pareto_select", "example_criteria"]


def _normalize(criteria):
    lo = jnp.min(criteria, axis=0, keepdims=True)
    hi = jnp.max(criteria, axis=0, keepdims=True)
    return (criteria - lo) / jnp.maximum(hi - lo, 1e-9)


def pareto_mask(criteria: jnp.ndarray, *, distributed_cfg: SkyConfig | None
                = None, mesh=None) -> jnp.ndarray:
    """(N,) bool — membership of each example in the Pareto front.

    criteria: (N, d) with smaller = better. Uses the blocked skyline for
    small N and the full parallel pipeline (partition/local/merge) when a
    SkyConfig is supplied.
    """
    c = _normalize(criteria)
    if distributed_cfg is None:
        return skyline_mask(c)
    buf, _ = parallel_skyline(c, cfg=distributed_cfg, mesh=mesh)
    # map compacted front back to membership by re-testing dominance
    return skyline_mask(c)


def pareto_select(criteria: jnp.ndarray, k: int):
    """Indices of up to k examples, Pareto-front members first (front
    members get priority 0, dominated examples ranked by a monotone
    score)."""
    c = _normalize(criteria)
    front = pareto_mask(c)
    score = jnp.sum(c, axis=-1) + jnp.where(front, 0.0, 1e3)
    order = jnp.argsort(score)
    return order[:k], front


def example_criteria(per_example_loss, lengths, recency):
    """A standard criteria vector: prefer hard (high-loss), long-enough,
    fresh examples. All axes mapped to smaller-is-better in [0, 1]."""
    hard = -per_example_loss          # harder = smaller
    short = -lengths.astype(jnp.float32)
    stale = recency.astype(jnp.float32)
    return _normalize(jnp.stack([hard, short, stale], axis=-1))
