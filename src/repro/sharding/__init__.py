"""Sharding utilities: ZeRO-1 optimizer-state specs and spec plumbing."""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import PSpec, plan_pspecs

__all__ = ["zero1_pspecs", "named_shardings", "zero1_shardings"]


def _used_axes(spec: P):
    used = set()
    for e in spec:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            used.add(a)
    return used


def zero1_pspecs(plan, rules, data_size: int, axis: str = "data"):
    """Optimizer-moment specs: params' specs with the `data` axis added on
    the first unsharded divisible dim — ZeRO-1 state sharding. XLA then
    reduce-scatters gradients into the update and all-gathers fresh params,
    which is exactly the ZeRO-1 communication pattern."""
    base = plan_pspecs(plan, rules)

    def extend(spec: P, leaf: PSpec):
        if axis in _used_axes(spec):
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, s in enumerate(leaf.shape):
            if entries[i] is None and s % data_size == 0 and s >= data_size:
                entries[i] = axis
                return P(*entries)
        return spec

    return jax.tree.map(extend, base, plan,
                        is_leaf=lambda x: isinstance(x, (P, PSpec)))


def named_shardings(pspecs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def zero1_shardings(plan, rules, mesh):
    data = mesh.shape.get("data", 1)
    return named_shardings(zero1_pspecs(plan, rules, data), mesh)
