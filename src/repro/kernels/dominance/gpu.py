"""GPU (Triton-lowered Pallas) backend for the blocked dominance test.

Same per-tile body as the TPU kernel
(:func:`repro.kernels.dominance.kernel._block_dominated`), different grid
contract: the TPU kernel OR-accumulates over reference blocks in a
*revisited output block*, which relies on the sequential TPU grid — GPU
grid programs are parallel, so that accumulator is not valid there.
This backend launches one program per candidate block (``grid=(C/BC,)``)
and walks the reference blocks in an in-kernel ``fori_loop``, carrying
the OR-reduction in registers; each output tile is written once by
exactly one program.

The reference-block loop bounds resident intermediates at
``block_r x block_c`` test elements — the dominance family's analogue of
the sweep's window tile (its `dominance_vmem_bytes` law is already tile-
shaped, so the Layer-2 verifier gates this backend unchanged).  The
attribute rows are padded to a multiple of ``D_PAD`` rather than capped
at it (per-backend ``max_d`` in `repro.kernels.backend`).  CI validates
the body bitwise in interpret mode (``gpu_interpret``); on a real GPU
runtime the same call compiles through the Triton lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dominance.kernel import D_PAD, _block_dominated

__all__ = ["dominated_mask_pallas_gpu"]


def _dominance_gpu_kernel(cands_ref, refs_ref, mask_ref, out_ref, *,
                          d: int, block_c: int, block_r: int, nrb: int,
                          lower_tri: bool):
    i = pl.program_id(0)
    x = cands_ref[...]  # (d_pad, BC)

    def body(j, acc):
        r = pl.load(refs_ref, (slice(None), pl.ds(j * block_r, block_r)))
        m = pl.load(mask_ref, (slice(None), pl.ds(j * block_r, block_r)))
        return acc | _block_dominated(
            x, r, m, d=d, block_c=block_c, block_r=block_r,
            lower_tri=lower_tri, roff=j * block_r, coff=i * block_c)

    red = jax.lax.fori_loop(0, nrb, body,
                            jnp.zeros((block_c,), jnp.bool_))
    out_ref[...] = red[None, :].astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("lower_tri", "block_c", "block_r", "interpret"))
def dominated_mask_pallas_gpu(
    cands_t: jnp.ndarray,
    refs_t: jnp.ndarray,
    ref_mask: jnp.ndarray,
    *,
    lower_tri: bool = False,
    block_c: int = 512,
    block_r: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked dominance-test kernel, one GPU program per candidate block.

    Same contract as
    :func:`repro.kernels.dominance.kernel.dominated_mask_pallas` except
    the attribute row count may be any multiple of ``D_PAD`` (wide d
    pads; extra rows are zero and inert).
    """
    d_pad, c = cands_t.shape
    _, r = refs_t.shape
    assert d_pad % D_PAD == 0, f"attribute rows must pad to {D_PAD}"
    assert refs_t.shape[0] == d_pad, (refs_t.shape, d_pad)
    assert c % block_c == 0 and r % block_r == 0, (c, r, block_c, block_r)

    kernel = functools.partial(
        _dominance_gpu_kernel, d=d_pad, block_c=block_c, block_r=block_r,
        nrb=r // block_r, lower_tri=lower_tri)
    return pl.pallas_call(
        kernel,
        grid=(c // block_c,),
        in_specs=[
            pl.BlockSpec((d_pad, block_c), lambda i: (0, i)),
            pl.BlockSpec((d_pad, r), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, c), jnp.int32),
        interpret=interpret,
    )(cands_t, refs_t, ref_mask)
