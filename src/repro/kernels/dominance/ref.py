"""Pure-jnp oracle for the blocked dominance kernel.

Dominance (paper Definition 1): ``t < s`` (t dominates s) iff
``all_k t[k] <= s[k]`` and ``any_k t[k] < s[k]``.

The kernel-level contract shared by :mod:`ref`, :mod:`kernel` and
:mod:`ops`::

    dominated_mask_ref(cands, refs, ref_mask, lower_tri=False) -> (C,) bool

``out[i] = any_j ref_mask[j] & (refs[j] < cands[i])`` and, when
``lower_tri`` is set (self-join on a score-sorted array), only refs with
``j < i`` are considered — sound because a monotone *strictly* increasing
score implies a dominator always sorts strictly earlier (SFS topological
order, paper §2).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["dominance_matrix_ref", "dominated_mask_ref"]


def dominance_matrix_ref(refs: jnp.ndarray, cands: jnp.ndarray) -> jnp.ndarray:
    """(R, C) bool matrix: ``out[j, i] = refs[j] dominates cands[i]``."""
    le = jnp.all(refs[:, None, :] <= cands[None, :, :], axis=-1)
    lt = jnp.any(refs[:, None, :] < cands[None, :, :], axis=-1)
    return le & lt


def dominated_mask_ref(
    cands: jnp.ndarray,
    refs: jnp.ndarray,
    ref_mask: jnp.ndarray | None = None,
    *,
    lower_tri: bool = False,
) -> jnp.ndarray:
    """Per-candidate: is it dominated by any (valid) reference point?

    Args:
      cands: (C, d) candidate points.
      refs: (R, d) reference points.
      ref_mask: (R,) validity of each reference row (None = all valid).
      lower_tri: if True, requires ``cands is refs`` semantically: ref j may
        only dominate cand i when ``j < i``.

    Returns:
      (C,) bool — True where the candidate is dominated.
    """
    dom = dominance_matrix_ref(refs, cands)  # (R, C)
    if ref_mask is not None:
        dom = dom & ref_mask[:, None]
    if lower_tri:
        r = refs.shape[0]
        c = cands.shape[0]
        tri = jnp.arange(r)[:, None] < jnp.arange(c)[None, :]
        dom = dom & tri
    return jnp.any(dom, axis=0)
