"""Jit'd public wrapper around the blocked dominance kernel.

This is the ONE call for pairwise dominance between two (possibly
different) point sets — pre-filter, eviction, NoSeq relative skylines,
representative filtering all route through it.  The local-phase SFS scan
does NOT: that is the fused sweep's job (``repro.kernels.sfs.sfs_sweep``,
one dispatch per partition batch).  Backend selection normally happens one
layer up (``repro.kernels.backend.resolve_spec(cfg.impl).dominance``);
the ``impl`` accepted here is the per-family string:

  * ``impl='pallas'``     — compiled Pallas TPU kernel (the production path).
  * ``impl='interpret'``  — same kernel body, interpret mode (CPU validation).
  * ``impl='gpu'``        — Triton-lowered Pallas kernel (gpu.py): one
                            program per candidate block, ref blocks
                            walked in-kernel (GPU grids are parallel).
  * ``impl='gpu_interpret'`` — the GPU body in interpret mode.
  * ``impl='jnp'``        — blocked pure-jnp fallback (fast on XLA:CPU).
  * ``impl='auto'``       — 'pallas' on TPU backends, 'gpu' on GPU
                            backends, 'jnp' elsewhere.

The attribute-width cap is per-implementation data
(`repro.kernels.backend.impl_max_d`): the TPU sublane layout caps at
d <= 8, the GPU layout pads attribute rows instead, and the jnp path
takes any d.

All paths implement the contract of :func:`ref.dominated_mask_ref` and are
tested against it (tests/test_dominance_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dominance import kernel as _kernel
from repro.kernels.dominance import ref as _ref

__all__ = ["dominated_mask"]

# refs-block size for the memory-bounded jnp path: bounds the (C, BR, d)
# broadcast intermediate.
_JNP_REF_BLOCK = 2048


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _dominated_mask_jnp(cands, refs, ref_mask, lower_tri):
    """Blocked pure-jnp path: loop over reference blocks, OR-accumulate."""
    c, d = cands.shape
    r = refs.shape[0]
    if r <= _JNP_REF_BLOCK:
        return _ref.dominated_mask_ref(cands, refs, ref_mask,
                                       lower_tri=lower_tri)

    rp = _ceil_to(r, _JNP_REF_BLOCK)
    refs_p = jnp.pad(refs, ((0, rp - r), (0, 0)))
    mask_p = jnp.pad(ref_mask, (0, rp - r))
    nb = rp // _JNP_REF_BLOCK
    cand_idx = jnp.arange(c)

    def body(b, acc):
        off = b * _JNP_REF_BLOCK
        rblk = jax.lax.dynamic_slice_in_dim(refs_p, off, _JNP_REF_BLOCK, 0)
        mblk = jax.lax.dynamic_slice_in_dim(mask_p, off, _JNP_REF_BLOCK, 0)
        le = jnp.all(rblk[:, None, :] <= cands[None, :, :], axis=-1)
        lt = jnp.any(rblk[:, None, :] < cands[None, :, :], axis=-1)
        dom = le & lt & mblk[:, None]
        if lower_tri:
            rid = off + jnp.arange(_JNP_REF_BLOCK)
            dom = dom & (rid[:, None] < cand_idx[None, :])
        return acc | jnp.any(dom, axis=0)

    return jax.lax.fori_loop(0, nb, body, jnp.zeros((c,), jnp.bool_))


def _dominated_mask_pallas(cands, refs, ref_mask, lower_tri, block_c,
                           block_r, interpret, gpu=False):
    c, d = cands.shape
    r = refs.shape[0]
    cp = _ceil_to(max(c, 1), block_c)
    rp = _ceil_to(max(r, 1), block_r)
    # the GPU layout pads the attribute rows to a multiple of the
    # sublane tile instead of capping at it
    d_pad = _ceil_to(max(d, 1), _kernel.D_PAD) if gpu else _kernel.D_PAD
    # Transposed layout with zero-padded attribute rows: 0 <= 0 keeps `le`
    # true and 0 < 0 keeps `lt` false, so padded attributes are inert.
    cands_t = jnp.zeros((d_pad, cp), cands.dtype)
    cands_t = cands_t.at[:d, :c].set(cands.T)
    refs_t = jnp.zeros((d_pad, rp), refs.dtype)
    refs_t = refs_t.at[:d, :r].set(refs.T)
    mask2d = jnp.zeros((1, rp), jnp.int32)
    mask2d = mask2d.at[0, :r].set(ref_mask.astype(jnp.int32))
    if gpu:
        from repro.kernels.dominance import gpu as _gpu
        out = _gpu.dominated_mask_pallas_gpu(
            cands_t, refs_t, mask2d, lower_tri=lower_tri, block_c=block_c,
            block_r=block_r, interpret=interpret)
    else:
        out = _kernel.dominated_mask_pallas(
            cands_t, refs_t, mask2d, lower_tri=lower_tri, block_c=block_c,
            block_r=block_r, interpret=interpret)
    return out[0, :c] > 0


@functools.partial(
    jax.jit,
    static_argnames=("lower_tri", "impl", "block_c", "block_r"))
def dominated_mask(
    cands: jnp.ndarray,
    refs: jnp.ndarray,
    ref_mask: jnp.ndarray | None = None,
    *,
    lower_tri: bool = False,
    impl: str = "auto",
    block_c: int = 512,
    block_r: int = 512,
) -> jnp.ndarray:
    """(C,) bool: for each candidate, is it dominated by a valid ref?

    See ref.dominated_mask_ref for exact semantics.
    """
    if cands.ndim != 2 or refs.ndim != 2:
        raise ValueError("cands/refs must be (N, d)")
    if ref_mask is None:
        ref_mask = jnp.ones((refs.shape[0],), jnp.bool_)
    if impl == "auto":
        backend = jax.default_backend()
        impl = {"tpu": "pallas", "gpu": "gpu"}.get(backend, "jnp")
    if impl == "jnp":
        # the jnp path has no attribute-padding layout, so any d works
        return _dominated_mask_jnp(cands, refs, ref_mask, lower_tri)
    if impl in ("pallas", "interpret", "gpu", "gpu_interpret"):
        # attribute-width caps are per-backend data — enforced after
        # impl resolution so wide-d inputs keep working on capless paths
        from repro.kernels.backend import impl_max_d
        cap = impl_max_d(impl)
        if cap is not None and cands.shape[1] > cap:
            raise ValueError(
                f"d > {cap} not supported by the Pallas kernel; "
                f"use impl='jnp'")
        return _dominated_mask_pallas(
            cands, refs, ref_mask, lower_tri, block_c, block_r,
            interpret=impl in ("interpret", "gpu_interpret"),
            gpu=impl in ("gpu", "gpu_interpret"))
    raise ValueError(f"unknown impl {impl!r}")
