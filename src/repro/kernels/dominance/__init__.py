from repro.kernels.dominance.ops import dominated_mask
from repro.kernels.dominance.ref import dominance_matrix_ref, dominated_mask_ref

__all__ = ["dominated_mask", "dominance_matrix_ref", "dominated_mask_ref"]
