"""Pallas TPU kernel for blocked dominance tests.

This is the compute hot-spot of skyline computation (paper §2: the
intrinsically quadratic dominance tests). The kernel computes, for a tile
of candidate points against a tile of reference points, whether each
candidate is dominated by any valid reference.

TPU-native layout (see DESIGN.md §3): points are stored **transposed** as
``(d_pad, N)`` so that the point index runs along the 128-wide lane
dimension and the (small, 2..8) attribute dimension sits in sublanes. The
pairwise comparison for one attribute k is then a rank-1 broadcast
``refs[k, :, None] <= cands[k, None, :]`` producing a well-shaped
``(BR, BC)`` VPU tile; the AND/OR reductions over the d attributes are a
short unrolled loop. This replaces SFS's scalar window scan with uniform
vector work while preserving its semantics (ops.py / sfs.py drive it in
score-sorted order, so the ``lower_tri`` mode implements the topological-
order property of the sort).

Grid: ``(num_cand_blocks, num_ref_blocks)`` with the ref-block index
innermost, so each output tile stays resident while it accumulates the
OR over all reference blocks.

VMEM per step (defaults BC=BR=512, d_pad=8, fp32):
  cands tile 512*8*4 = 16 KiB, refs tile 16 KiB, mask 2 KiB, out 2 KiB,
  (BR, BC) intermediates 512*512*4 = 1 MiB  -> comfortably < 16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dominated_mask_pallas", "dominance_vmem_bytes", "D_PAD"]

D_PAD = 8  # attribute dim padded to one fp32 sublane tile


def _block_dominated(x, r, m, *, d: int, block_c: int, block_r: int,
                     lower_tri: bool, roff, coff):
    """(BC,) bool: each candidate of the ``(d, BC)`` tile dominated by a
    valid reference of the ``(d, BR)`` tile — the SHARED per-tile body
    of the TPU kernel below and the GPU kernel (gpu.py).  ``roff`` /
    ``coff`` are the tiles' global row/column offsets (only consulted in
    ``lower_tri`` self-join mode)."""
    le = jnp.ones((block_r, block_c), dtype=jnp.bool_)
    lt = jnp.zeros((block_r, block_c), dtype=jnp.bool_)
    for k in range(d):  # unrolled: d is a static 2..8 (padded rows inert)
        rk = r[k, :][:, None]   # (BR, 1)
        xk = x[k, :][None, :]   # (1, BC)
        le = le & (rk <= xk)
        lt = lt | (rk < xk)
    dom = le & lt & (m[0, :][:, None] > 0)

    if lower_tri:
        rid = roff + jax.lax.broadcasted_iota(
            jnp.int32, (block_r, block_c), 0)
        cid = coff + jax.lax.broadcasted_iota(
            jnp.int32, (block_r, block_c), 1)
        dom = dom & (rid < cid)

    return jnp.any(dom, axis=0)  # (BC,)


def _dominance_kernel(cands_ref, refs_ref, mask_ref, out_ref, *, d: int,
                      block_c: int, block_r: int, lower_tri: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    red = _block_dominated(
        cands_ref[...], refs_ref[...], mask_ref[...], d=d,
        block_c=block_c, block_r=block_r, lower_tri=lower_tri,
        roff=j * block_r, coff=i * block_c)
    out_ref[...] = out_ref[...] | red[None, :].astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("lower_tri", "block_c", "block_r", "interpret"))
def dominated_mask_pallas(
    cands_t: jnp.ndarray,
    refs_t: jnp.ndarray,
    ref_mask: jnp.ndarray,
    *,
    lower_tri: bool = False,
    block_c: int = 512,
    block_r: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked dominance-test kernel.

    Args:
      cands_t: (D_PAD, C) transposed candidates; C % block_c == 0.
      refs_t:  (D_PAD, R) transposed references; R % block_r == 0.
      ref_mask: (1, R) int32 validity (0 = padding / invalid row).
      lower_tri: self-join mode — ref j may only dominate cand i if j < i
        (global indices). Requires cands_t and refs_t to be the same array.
      interpret: run the kernel body in interpret mode (CPU validation).

    Returns:
      (1, C) int32 — nonzero where the candidate is dominated.
    """
    d_pad, c = cands_t.shape
    _, r = refs_t.shape
    assert d_pad == D_PAD, f"attribute dim must be padded to {D_PAD}"
    assert c % block_c == 0 and r % block_r == 0, (c, r, block_c, block_r)

    grid = (c // block_c, r // block_r)
    kernel = functools.partial(
        _dominance_kernel, d=d_pad, block_c=block_c, block_r=block_r,
        lower_tri=lower_tri)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((D_PAD, block_c), lambda i, j: (0, i)),
            pl.BlockSpec((D_PAD, block_r), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_r), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, c), jnp.int32),
        interpret=interpret,
    )(cands_t, refs_t, ref_mask)


def dominance_vmem_bytes(*, block_c: int, block_r: int,
                         itemsize: int = 4) -> int:
    """Static per-grid-step VMEM footprint estimate for the dominance
    kernel: the two attribute tiles plus the ``(BR, BC)`` le/lt test
    intermediates (booleans at one byte, iota comparisons fused — see
    `repro.kernels.sfs.kernel.sweep_vmem_bytes` for the accounting
    conventions). Gated per compiled configuration by the static
    verifier (`repro.analysis`)."""
    io = D_PAD * (block_c + block_r) * itemsize \
        + (block_r + block_c) * 4               # mask + out (int32)
    tests = 2 * block_r * block_c               # le, lt (bool)
    return io + tests
