"""Persistent per-topology kernel autotuner.

The calibration machinery measures, rather than guesses, the dispatch
policy of the live topology (`repro.serve.engine.calibrate_shard_threshold`
times vmap vs every mesh factoring).  This module extends it to the
kernel *geometry*: :func:`calibrate_kernels` times candidate
``(block, wtile)`` pairs per ``(family, d, dtype)`` on the runtime the
``'auto'`` impl actually resolves to, verifies every candidate bit-for-bit
against the per-pair reference, and persists the winners as a
:class:`TuningTable` — a JSON artifact CI uploads and prod loads, so both
run the same tuned geometry:

    table = calibrate_kernels(engine)          # applies to the engine
    table.save("results/kernel_tuning.json")
    ...
    REPRO_KERNEL_TUNING=results/kernel_tuning.json python serve.py

Resolution order when the engine answers an ``impl='auto'`` request:
its own calibrated table (``engine.kernel_tuning``, set by
``calibrate_kernels(engine)``), else the process default
(:func:`set_default_table`, lazily loaded from the
``REPRO_KERNEL_TUNING`` env var — `repro.launch.env` plumbs it).  A
config that pins ``wtile`` explicitly, or any non-'auto' ``impl``, is
never overridden: the table tunes only what the user left to 'auto'.

Every tuned geometry is pure schedule — the sweep contract guarantees
any (block, wtile) is bit-identical to any other — so applying a table
can change performance and buffer padding, never membership decisions.
Candidates that fail the bitwise check (a broken backend, a miscompile)
are excluded from winning and reported with ``bitwise_ok=False``; CI
fails on any such entry (benchmarks/run.py ``kernel_autotune``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TuneEntry", "TuningTable", "calibrate_kernels",
           "default_table", "set_default_table", "tuning_key"]

ENV_VAR = "REPRO_KERNEL_TUNING"


def tuning_key(family: str, d: int, dtype) -> str:
    """Canonical table key: ``family/d=D/dtype=NAME``."""
    return f"{family}/d={int(d)}/dtype={jnp.dtype(dtype).name}"


@dataclasses.dataclass(frozen=True)
class TuneEntry:
    """One winning kernel geometry for a (family, d, dtype) key."""
    block: int
    wtile: int
    time_us: float
    impl: str                 # the impl string the timing ran under
    bitwise_ok: bool = True   # vs the per-pair / full-matrix reference


@dataclasses.dataclass
class TuningTable:
    """Tuned (block, wtile) per ``family/d=D/dtype=NAME`` key, plus the
    topology it was measured on (informational — a table is valid
    anywhere, it is just only *optimal* on the topology that made it)."""
    entries: dict[str, TuneEntry] = dataclasses.field(default_factory=dict)
    topology: dict[str, Any] = dataclasses.field(default_factory=dict)

    def lookup(self, family: str, d: int, dtype) -> TuneEntry | None:
        return self.entries.get(tuning_key(family, d, dtype))

    def __len__(self) -> int:
        return len(self.entries)

    def to_json(self) -> dict:
        return {"version": 1, "topology": self.topology,
                "entries": {k: dataclasses.asdict(e)
                            for k, e in self.entries.items()}}

    @classmethod
    def from_json(cls, doc: dict) -> "TuningTable":
        entries = {k: TuneEntry(**{f: v[f] for f in
                                   ("block", "wtile", "time_us", "impl",
                                    "bitwise_ok") if f in v})
                   for k, v in doc.get("entries", {}).items()}
        return cls(entries=entries, topology=doc.get("topology", {}))

    def save(self, path: str) -> str:
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        with open(path) as f:
            return cls.from_json(json.load(f))


# -- process-default table (env-loadable) ----------------------------------

_DEFAULT: TuningTable | None = None
_DEFAULT_LOADED = False


def set_default_table(table: TuningTable | None) -> None:
    """Install ``table`` as the process default (None clears it and
    re-arms the env-var load)."""
    global _DEFAULT, _DEFAULT_LOADED
    _DEFAULT = table
    _DEFAULT_LOADED = table is not None


def default_table() -> TuningTable | None:
    """The process-default tuning table: whatever `set_default_table`
    installed, else a one-time lazy load from ``$REPRO_KERNEL_TUNING``
    (missing/invalid paths degrade to None — an untuned process must
    run, not crash)."""
    global _DEFAULT, _DEFAULT_LOADED
    if not _DEFAULT_LOADED:
        _DEFAULT_LOADED = True
        path = os.environ.get(ENV_VAR)
        if path:
            try:
                _DEFAULT = TuningTable.load(path)
            except (OSError, ValueError, KeyError, TypeError):
                _DEFAULT = None
    return _DEFAULT


# -- calibration -----------------------------------------------------------

def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _bitwise_equal(a, b) -> bool:
    """Bit-level equality for float buffers (NaN-proof, -0.0-strict)."""
    a, b = np.asarray(a), np.asarray(b)
    if a.shape != b.shape or a.dtype != b.dtype:
        return False
    return bool(np.array_equal(a.view(np.uint8), b.view(np.uint8)))


def _interleaved_best(cands: dict[str, Any], repeat: int) -> dict[str, float]:
    """Best-of-``repeat`` wall time per candidate thunk, rounds
    interleaved (and order alternated) so clock drift and turbo decay
    hit every candidate equally — the `local_phase` benchmark idiom."""
    for fn in cands.values():     # warmup pays compilation
        jax.block_until_ready(fn())
    best = {k: float("inf") for k in cands}
    for r in range(repeat):
        order = list(cands) if r % 2 == 0 else list(reversed(cands))
        for k in order:
            t0 = time.perf_counter()
            jax.block_until_ready(cands[k]())
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def _sweep_candidates(blocks: Sequence[int], capacity: int,
                      ) -> list[tuple[int, int]]:
    """(block, wtile) grid: untiled, one-block and two-block tiles per
    block size, filtered to divisors of that block's window."""
    out = []
    for b in blocks:
        wcap = _ceil_to(capacity, b)
        for t in (0, b, 2 * b):
            if t > wcap or (t and wcap % t):
                continue
            out.append((b, t))
    return out


def calibrate_kernels(engine=None, *,
                      ds: Sequence[int] = (4,),
                      dtypes: Sequence[Any] = (jnp.float32,),
                      n: int = 16_384, p: int = 8,
                      capacity: int | None = None,
                      blocks: Sequence[int] = (128, 256, 512),
                      repeat: int = 3, apply: bool = True,
                      verify: bool = True,
                      path: str | None = None) -> dict[str, Any]:
    """Time candidate kernel geometries on the live topology and build
    the winning :class:`TuningTable`.

    For every ``(d, dtype)``: the *sweep* family times each candidate
    ``(block, wtile)`` through `local_skyline_batch` on a synthetic
    ``(p, n/p, d)`` partition batch (interleaved best-of-``repeat``),
    and the *dominance* family times each block size through
    `dominated_mask`.  With ``verify=True`` (the default) every sweep
    candidate is checked bit-for-bit against the per-pair reference and
    every dominance candidate against the full-matrix reference before
    it may win; divergent candidates are recorded with
    ``bitwise_ok=False`` and never selected.

    ``engine`` supplies the config whose 'auto' resolution the table
    will serve (capacity, impl) and — with ``apply=True`` — receives the
    table as ``engine.kernel_tuning``; ``engine=None`` calibrates the
    process default config instead and installs the table with
    `set_default_table`.  ``path`` additionally persists the JSON
    artifact.  Returns a report dict (``table``, per-key candidate
    timings, ``divergent`` keys).
    """
    from repro.core.parallel import SkyConfig
    from repro.core.sfs import local_skyline_batch
    from repro.kernels.backend import impl_max_d, resolve_spec
    from repro.kernels.dominance import dominated_mask
    from repro.kernels.dominance.ref import dominated_mask_ref

    cfg = engine.cfg if engine is not None else SkyConfig()
    capacity = int(capacity or cfg.capacity)
    spec = resolve_spec(cfg.impl)
    table = TuningTable(topology={
        "platform": jax.default_backend(),
        "devices": len(jax.devices()),
        "impl": spec.name, "n": int(n), "p": int(p),
        "capacity": capacity})
    report: dict[str, Any] = {"impl": spec.name, "keys": {},
                              "divergent": []}

    psz = _ceil_to(max(n // max(p, 1), 1), max(blocks))
    for d in ds:
        if spec.max_d is not None and d > spec.max_d:
            continue
        for dtype in dtypes:
            rng = np.random.default_rng(d * 1000 + 17)
            # quantized coordinates: dense dominance ties, the
            # regime where the window test does real work
            pts = jnp.asarray(
                np.round(rng.random((p, psz, d)) * 64) / 64, dtype)
            mask = jnp.ones((p, psz), jnp.bool_)

            # --- sweep family: (block, wtile) candidates --------------
            cands = _sweep_candidates(blocks, capacity)
            thunks = {
                f"b{b}/t{t}": (lambda b=b, t=t: local_skyline_batch(
                    pts, mask, capacity=capacity, block=b,
                    impl=cfg.impl, wtile=t).points)
                for (b, t) in cands}
            times = _interleaved_best(thunks, repeat)
            ok: dict[str, bool] = {}
            if verify:
                for (b, t) in cands:
                    got = local_skyline_batch(pts, mask,
                                              capacity=capacity, block=b,
                                              impl=cfg.impl, wtile=t)
                    ref = local_skyline_batch(pts, mask,
                                              capacity=capacity, block=b,
                                              impl="perpair")
                    ok[f"b{b}/t{t}"] = (
                        _bitwise_equal(got.points, ref.points)
                        and _bitwise_equal(got.mask, ref.mask)
                        and _bitwise_equal(got.count, ref.count))
            else:
                ok = {k: True for k in thunks}
            key = tuning_key("sweep", d, dtype)
            report["keys"][key] = {
                "times_us": {k: round(v * 1e6, 2)
                             for k, v in times.items()},
                "bitwise_ok": ok}
            valid = [k for k in times if ok[k]]
            if not valid:
                report["divergent"].append(key)
            else:
                win = min(valid, key=times.get)
                wb, wt = (int(x[1:]) for x in win.split("/"))
                table.entries[key] = TuneEntry(
                    block=wb, wtile=wt,
                    time_us=round(times[win] * 1e6, 2),
                    impl=spec.name,
                    bitwise_ok=all(ok[k] for k in valid))
                if any(not v for v in ok.values()):
                    report["divergent"].append(key)

            # --- dominance family: block candidates -------------------
            if impl_max_d(spec.dominance) is not None \
                    and d > impl_max_d(spec.dominance):
                continue
            # one partition's worth is representative and keeps the
            # O(n^2) dominance timing off the critical calibration path
            flat = pts[0]
            fm = mask[0]
            dthunks = {
                f"b{b}": (lambda b=b: dominated_mask(
                    flat, flat, fm, impl=spec.dominance,
                    block_c=b, block_r=b))
                for b in blocks}
            dtimes = _interleaved_best(dthunks, repeat)
            dok: dict[str, bool] = {}
            if verify:
                dref = dominated_mask_ref(flat, flat, fm)
                for b in blocks:
                    got = dominated_mask(flat, flat, fm,
                                         impl=spec.dominance,
                                         block_c=b, block_r=b)
                    dok[f"b{b}"] = _bitwise_equal(got, dref)
            else:
                dok = {k: True for k in dthunks}
            dkey = tuning_key("dominance", d, dtype)
            report["keys"][dkey] = {
                "times_us": {k: round(v * 1e6, 2)
                             for k, v in dtimes.items()},
                "bitwise_ok": dok}
            dvalid = [k for k in dtimes if dok[k]]
            if not dvalid:
                report["divergent"].append(dkey)
            else:
                dwin = min(dvalid, key=dtimes.get)
                table.entries[dkey] = TuneEntry(
                    block=int(dwin[1:]), wtile=0,
                    time_us=round(dtimes[dwin] * 1e6, 2),
                    impl=spec.dominance,
                    bitwise_ok=all(dok[k] for k in dvalid))
                if any(not v for v in dok.values()):
                    report["divergent"].append(dkey)

    if apply:
        if engine is not None:
            engine.kernel_tuning = table
        else:
            set_default_table(table)
    if path:
        table.save(path)
        report["path"] = path
    report["table"] = table
    report["applied"] = apply
    return report
