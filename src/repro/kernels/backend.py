"""Pluggable kernel-backend layer.

Every compute-kernel choice in the skyline pipeline is described by one
immutable :class:`KernelSpec`, resolved from the ``SkyConfig.impl`` string
(``'auto' | 'pallas' | 'interpret' | 'jnp' | 'perpair' | ...``).  The spec
names the implementation of the two kernel families:

  * ``sweep``     — the fused local-phase SFS sweep
                    (:func:`repro.kernels.sfs.sfs_sweep`), the one call
                    every block-SFS execution routes through.
  * ``dominance`` — the pairwise blocked dominance test
                    (:func:`repro.kernels.dominance.dominated_mask`), used
                    by the pre-filter / eviction / NoSeq / representative
                    passes that compare two *different* point sets.

String values are backward compatible: the historical ``impl`` strings
(``auto``/``pallas``/``interpret``/``jnp``) resolve to specs whose two
families use that same implementation, so existing configs behave exactly
as before.  New backends (e.g. the per-pair legacy sweep kept as a
reference and benchmark baseline) are added with :func:`register_backend`
without touching any call site — callers hold only the ``impl`` string.

``KernelSpec`` is a frozen dataclass, hence hashable: it can be a
``static_argnames`` jit argument and a cache key.
"""

from __future__ import annotations

import dataclasses

import jax

__all__ = ["KernelSpec", "resolve_spec", "register_backend",
           "available_backends", "vmem_estimate"]

# implementations understood by repro.kernels.dominance.ops.dominated_mask
_DOMINANCE_IMPLS = ("jnp", "pallas", "interpret")
# implementations understood by repro.kernels.sfs.ops.sfs_sweep
_SWEEP_IMPLS = ("jnp", "pallas", "interpret", "perpair")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Resolved kernel choices for one pipeline configuration.

    Attributes:
      name: registry key (what ``SkyConfig.impl`` held, post-'auto').
      sweep: local-phase SFS sweep implementation.
      dominance: pairwise dominance-kernel implementation.
    """
    name: str
    sweep: str
    dominance: str

    def __post_init__(self):
        if self.sweep not in _SWEEP_IMPLS:
            raise ValueError(f"unknown sweep impl {self.sweep!r}; "
                             f"valid: {_SWEEP_IMPLS}")
        if self.dominance not in _DOMINANCE_IMPLS:
            raise ValueError(f"unknown dominance impl {self.dominance!r}; "
                             f"valid: {_DOMINANCE_IMPLS}")


_REGISTRY: dict[str, KernelSpec] = {
    # the historical impl strings: both kernel families use that impl
    "jnp": KernelSpec("jnp", sweep="jnp", dominance="jnp"),
    "pallas": KernelSpec("pallas", sweep="pallas", dominance="pallas"),
    "interpret": KernelSpec("interpret", sweep="interpret",
                            dominance="interpret"),
    # legacy local phase: dominance kernel dispatched once per
    # (window-block, candidate-block) pair — kept as the bit-for-bit
    # reference and the benchmark baseline for the fused sweep
    "perpair": KernelSpec("perpair", sweep="perpair", dominance="jnp"),
    "perpair_interpret": KernelSpec("perpair_interpret", sweep="perpair",
                                    dominance="interpret"),
}


def register_backend(spec: KernelSpec, *, overwrite: bool = False) -> None:
    """Add a backend under ``spec.name`` (used as the ``impl`` string)."""
    if spec.name == "auto":
        raise ValueError("'auto' is reserved for runtime resolution")
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec


def available_backends() -> tuple[str, ...]:
    """Registered backend names (excluding the 'auto' alias)."""
    return tuple(sorted(_REGISTRY))


def resolve_spec(impl: str | KernelSpec = "auto") -> KernelSpec:
    """``SkyConfig.impl`` -> :class:`KernelSpec`.

    ``'auto'`` resolves to the compiled Pallas backend on TPU runtimes and
    the blocked pure-jnp backend elsewhere; every other string is looked
    up in the registry.  A :class:`KernelSpec` passes through unchanged.
    """
    if isinstance(impl, KernelSpec):
        return impl
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    try:
        return _REGISTRY[impl]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {impl!r}; registered: "
            f"{', '.join(available_backends())} (or 'auto')") from None


def vmem_estimate(cfg_block: int, cfg_capacity: int, *,
                  itemsize: int = 4) -> dict[str, int]:
    """Per-kernel-family VMEM footprint estimate (bytes per grid step)
    for one pipeline configuration, at the W x BC tiling the Pallas
    backend would compile: ``BC = cfg.block`` and ``W`` = the capacity
    rounded up to the block (the merge stage's block-SFS window, the
    largest sweep window in the fused program).

    Reported for every resolved backend — a host that resolves 'auto'
    to the jnp reference still serves configs that later compile on
    TPU, so the bound gates the tiling, not the runtime. The static
    verifier (`repro.analysis`) fails any configuration whose estimate
    exceeds the per-core VMEM cap."""
    from repro.kernels.dominance.kernel import dominance_vmem_bytes
    from repro.kernels.sfs.kernel import sweep_vmem_bytes
    block = max(int(cfg_block), 1)
    wcap = -(-max(int(cfg_capacity), 1) // block) * block
    return {
        "sweep": sweep_vmem_bytes(block_c=block, wcap=wcap,
                                  itemsize=itemsize),
        "dominance": dominance_vmem_bytes(block_c=block, block_r=block,
                                          itemsize=itemsize),
        "window_rows": wcap,
        "block": block,
    }
