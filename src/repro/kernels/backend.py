"""Pluggable kernel-backend layer.

Every compute-kernel choice in the skyline pipeline is described by one
immutable :class:`KernelSpec`, resolved from the ``SkyConfig.impl`` string
(``'auto' | 'pallas' | 'interpret' | 'gpu' | 'jnp' | 'perpair' | ...``).
The spec names the implementation of the two kernel families:

  * ``sweep``     — the fused local-phase SFS sweep
                    (:func:`repro.kernels.sfs.sfs_sweep`), the one call
                    every block-SFS execution routes through.
  * ``dominance`` — the pairwise blocked dominance test
                    (:func:`repro.kernels.dominance.dominated_mask`), used
                    by the pre-filter / eviction / NoSeq / representative
                    passes that compare two *different* point sets.

String values are backward compatible: the historical ``impl`` strings
(``auto``/``pallas``/``interpret``/``jnp``) resolve to specs whose two
families use that same implementation, so existing configs behave exactly
as before.  New backends (the per-pair legacy sweep kept as a reference
and benchmark baseline, the Triton-lowered GPU kernels) are added with
:func:`register_backend` without touching any call site — callers hold
only the ``impl`` string.

``KernelSpec`` is a frozen dataclass, hence hashable: it can be a
``static_argnames`` jit argument and a cache key.

The tiling/VMEM contract every backend must keep
------------------------------------------------

A backend's compiled sweep may hold the ``(d_pad, W)`` window buffer
resident (it is O(W) small), but its *materialized test intermediates*
must respect the window tile: with ``SkyConfig.wtile = T > 0`` no more
than ``T x block`` comparison elements (plus the ``block x block``
self-test) may exist at once — the window test and the append iterate
over W/T sub-blocks (`repro.kernels.sfs.kernel._tiled_block_step` is the
shared body; untiled ``wtile=0`` means one whole-window tile).  The tile
is pure schedule: every (backend, wtile) pair must stay bit-for-bit
identical to ``sfs_sweep_perpair`` (property-tested in
tests/test_sfs_kernel.py).  :func:`vmem_estimate` states the footprint
law in bytes and the Layer-2 static verifier (`repro.analysis`) gates
every compiled configuration against the 16 MiB/core cap — a new backend
whose footprint law differs must override the estimate, not the cap.

Attribute-width caps are per-backend data, not a global constant:
``KernelSpec.max_d`` is the widest supported ``d`` (``None`` = unbounded).
The TPU kernels pack attributes into one 8-row fp32 sublane tile
(``max_d=8``); the GPU kernels pad to any multiple of 8; the pure-jnp
and per-pair paths take any ``d``.
"""

from __future__ import annotations

import dataclasses

import jax

__all__ = ["KernelSpec", "resolve_spec", "register_backend",
           "available_backends", "vmem_estimate", "impl_max_d"]

# implementations understood by repro.kernels.dominance.ops.dominated_mask
_DOMINANCE_IMPLS = ("jnp", "pallas", "interpret", "gpu", "gpu_interpret")
# implementations understood by repro.kernels.sfs.ops.sfs_sweep
_SWEEP_IMPLS = ("jnp", "pallas", "interpret", "gpu", "gpu_interpret",
                "perpair")

# widest d each per-family implementation string supports (None =
# unbounded). The TPU Pallas layout packs attributes into one 8-row
# sublane tile; the GPU layout pads the attribute rows instead.
_IMPL_MAX_D = {"jnp": None, "perpair": None,
               "pallas": 8, "interpret": 8,
               "gpu": None, "gpu_interpret": None}


def impl_max_d(impl: str) -> int | None:
    """Widest ``d`` the per-family implementation string supports."""
    return _IMPL_MAX_D.get(impl)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Resolved kernel choices for one pipeline configuration.

    Attributes:
      name: registry key (what ``SkyConfig.impl`` held, post-'auto').
      sweep: local-phase SFS sweep implementation.
      dominance: pairwise dominance-kernel implementation.
      max_d: widest attribute dimension the spec's compiled layouts
        support (None = unbounded); the min over its families' caps.
    """
    name: str
    sweep: str
    dominance: str
    max_d: int | None = None

    def __post_init__(self):
        if self.sweep not in _SWEEP_IMPLS:
            raise ValueError(f"unknown sweep impl {self.sweep!r}; "
                             f"valid: {_SWEEP_IMPLS}")
        if self.dominance not in _DOMINANCE_IMPLS:
            raise ValueError(f"unknown dominance impl {self.dominance!r}; "
                             f"valid: {_DOMINANCE_IMPLS}")


def _spec(name, sweep, dominance):
    caps = [c for c in (impl_max_d(sweep), impl_max_d(dominance))
            if c is not None]
    return KernelSpec(name, sweep=sweep, dominance=dominance,
                      max_d=min(caps) if caps else None)


_REGISTRY: dict[str, KernelSpec] = {
    # the historical impl strings: both kernel families use that impl
    "jnp": _spec("jnp", sweep="jnp", dominance="jnp"),
    "pallas": _spec("pallas", sweep="pallas", dominance="pallas"),
    "interpret": _spec("interpret", sweep="interpret",
                       dominance="interpret"),
    # Triton-lowered Pallas on GPU runtimes: same kernel bodies, one
    # program per partition (GPU grids are parallel — no revisited-block
    # accumulators); gpu_interpret is its CPU-validation twin
    "gpu": _spec("gpu", sweep="gpu", dominance="gpu"),
    "gpu_interpret": _spec("gpu_interpret", sweep="gpu_interpret",
                           dominance="gpu_interpret"),
    # legacy local phase: dominance kernel dispatched once per
    # (window-block, candidate-block) pair — kept as the bit-for-bit
    # reference and the benchmark baseline for the fused sweep
    "perpair": _spec("perpair", sweep="perpair", dominance="jnp"),
    "perpair_interpret": _spec("perpair_interpret", sweep="perpair",
                               dominance="interpret"),
}


def register_backend(spec: KernelSpec, *, overwrite: bool = False) -> None:
    """Add a backend under ``spec.name`` (used as the ``impl`` string)."""
    if spec.name == "auto":
        raise ValueError("'auto' is reserved for runtime resolution")
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec


def available_backends() -> tuple[str, ...]:
    """Registered backend names (excluding the 'auto' alias)."""
    return tuple(sorted(_REGISTRY))


def resolve_spec(impl: str | KernelSpec = "auto") -> KernelSpec:
    """``SkyConfig.impl`` -> :class:`KernelSpec`.

    ``'auto'`` resolves to the compiled Pallas backend on TPU runtimes,
    the Triton-lowered Pallas backend on GPU runtimes, and the blocked
    pure-jnp backend elsewhere; every other string is looked up in the
    registry.  A :class:`KernelSpec` passes through unchanged.  (The
    tuned (block, wtile) geometry of an 'auto' config comes from the
    persisted tuning table — `repro.kernels.tuning` — consulted by the
    engine's config resolution, not here: the spec names *which* kernels
    run, the table names *how* they are tiled.)
    """
    if isinstance(impl, KernelSpec):
        return impl
    if impl == "auto":
        backend = jax.default_backend()
        impl = {"tpu": "pallas", "gpu": "gpu"}.get(backend, "jnp")
    try:
        return _REGISTRY[impl]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {impl!r}; registered: "
            f"{', '.join(available_backends())} (or 'auto')") from None


def vmem_estimate(cfg_block: int, cfg_capacity: int, *, wtile: int = 0,
                  itemsize: int = 4) -> dict[str, int]:
    """Per-kernel-family VMEM footprint estimate (bytes per grid step)
    for one pipeline configuration, at the tiling the Pallas backends
    would compile: ``BC = cfg.block``, ``W`` = the capacity rounded up
    to the block (the merge stage's block-SFS window, the largest sweep
    window in the fused program), and ``wtile`` the window tile
    (normalized exactly as the sweep entry normalizes it: <= 0 means
    untiled/whole-window, a non-divisor of W falls back to the block).

    Reported for every resolved backend — a host that resolves 'auto'
    to the jnp reference still serves configs that later compile on
    TPU, so the bound gates the tiling, not the runtime. The static
    verifier (`repro.analysis`) fails any configuration whose estimate
    exceeds the per-core VMEM cap; the window tile is what keeps the
    sweep under the cap at large capacities (W x BC elements resident
    untiled, wtile x BC tiled)."""
    from repro.kernels.dominance.kernel import dominance_vmem_bytes
    from repro.kernels.sfs.kernel import sweep_vmem_bytes
    from repro.kernels.sfs.ops import _normalize_wtile
    block = max(int(cfg_block), 1)
    wcap = -(-max(int(cfg_capacity), 1) // block) * block
    wtile = _normalize_wtile(wtile, wcap, block)
    return {
        "sweep": sweep_vmem_bytes(block_c=block, wcap=wcap, wtile=wtile,
                                  itemsize=itemsize),
        "dominance": dominance_vmem_bytes(block_c=block, block_r=block,
                                          itemsize=itemsize),
        "window_rows": wcap,
        "window_tile": wtile,
        "block": block,
    }
