"""Custom compute kernels behind the pluggable backend layer.

Two kernel families cover every dominance test in the pipeline, each with
ONE public call:

  * ``repro.kernels.sfs.sfs_sweep`` — the fused local-phase SFS sweep:
    the entire sorted scan of a batch of partitions (window test +
    lower-triangular self-test + append) in a single dispatch.  All
    block-SFS execution (``repro.core.sfs.local_skyline_batch`` and its
    thin ``block_sfs`` wrapper) routes through it.
  * ``repro.kernels.dominance.dominated_mask`` — the blocked pairwise
    dominance test between two different point sets (pre-filter,
    eviction, NoSeq relative skylines, representative filtering).

Implementations (Pallas TPU kernel / interpret mode / blocked pure-jnp /
legacy per-pair reference) are selected by ``repro.kernels.backend``:
``SkyConfig.impl`` resolves to a :class:`~repro.kernels.backend.KernelSpec`
naming the impl of each family, and new backends plug in via
``register_backend`` without touching call sites.

This package stays import-light on purpose: submodules are imported
explicitly by their users (``repro.core`` imports kernels, never the
other way around), keeping the kernel layer free of core dependencies.
"""
