"""Pallas TPU kernel for the fused local-phase SFS sweep.

One ``pallas_call`` executes the *entire* sorted Sort-Filter-Skyline scan
for a batch of partitions: grid ``(partition, candidate_block)`` with the
candidate-block index innermost, so each partition's window buffer, window
mask and running count stay resident in on-chip memory across its whole
scan (they are carried in the revisited output blocks — the same residency
trick the blocked dominance kernel uses for its OR-accumulator — with the
count in SMEM).  This replaces the seed's one-kernel-dispatch-per
(window-block, candidate-block) pair inside an XLA ``fori_loop``: the
window test, the lower-triangular in-block self-test and the append are
fused into a single kernel body, so a whole partition batch is one launch
with no host-visible intermediate state.

Layout follows the dominance kernel (DESIGN.md §3): points are stored
transposed as ``(d_pad, N)`` so the point index runs along the 128-wide
lane dimension and the (small, 2..8) attribute dimension sits in sublanes;
per-attribute comparisons are rank-1 ``(W, BC)`` / ``(BC, BC)`` VPU
broadcasts unrolled over the static ``d``.  The append is scatter-free: a
one-hot ``(BC, W)`` slot map built from the in-block prefix count routes
each kept candidate to its window slot with a masked integer-bit sum
(exactly one non-zero contributor per slot and integer adds are exact,
so the copy preserves every bit, -0.0 included), which keeps the kernel
free of dynamic-index stores.

Semantics are bit-for-bit those of the per-pair reference
(:func:`repro.kernels.sfs.ref.sfs_sweep_perpair`, the seed ``block_sfs``
body): identical keep decisions, identical slot assignment (first ``W``
keeps in score order, later keeps dropped), identical running count.

VMEM note (the tiling contract new backends must keep): untiled
(``wtile=0``) the window test materializes ``(W, BC)`` intermediates and
the append a ``(BC, W)`` one-hot, so ``W * BC`` elements must fit in VMEM
alongside the ``(d_pad, W)`` window — comfortable for the serving-regime
defaults (W <= 4096, BC <= 512, fp32: < 10 MiB).  With ``wtile=T`` the
window test and the append iterate over W/T window sub-blocks
(`_tiled_block_step`), so the materialized intermediates shrink to
``T * BC`` elements and the resident footprint is O(T x BC) no matter the
capacity — only the (small, ``d_pad * W``) window buffer itself scales
with W.  `sweep_vmem_bytes` states both laws in bytes and the static
verifier (`repro.analysis`) gates every compiled configuration against
the 16 MiB/core cap; all tilings are bit-for-bit identical (the tile only
changes the schedule, never a keep decision).  On real TPUs ``wtile``
should be a multiple of the 128-wide lane tile for aligned dynamic
slices.  Interpret mode (the CPU validation path) has no such limits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sfs_sweep_pallas", "sweep_vmem_bytes", "D_PAD"]

D_PAD = 8  # attribute dim padded to one fp32 sublane tile


def _self_test(x, *, d: int, block_c: int):
    """(BC,) bool: dominated within the block by an earlier (smaller-
    score) row — the SFS topological-order property makes this lower-
    triangular (invalid rows are sentinel-filled, hence inert as refs)."""
    le_s = jnp.ones((block_c, block_c), jnp.bool_)
    lt_s = jnp.zeros((block_c, block_c), jnp.bool_)
    for k in range(d):
        xr = x[k, :][:, None]
        xc = x[k, :][None, :]
        le_s = le_s & (xr <= xc)
        lt_s = lt_s | (xr < xc)
    rid = jax.lax.broadcasted_iota(jnp.int32, (block_c, block_c), 0)
    cid = jax.lax.broadcasted_iota(jnp.int32, (block_c, block_c), 1)
    return jnp.any(le_s & lt_s & (rid < cid), axis=0)


def _tiled_block_step(x, xm, count, win_ref, wmask_ref, *, d: int,
                      block_c: int, wcap: int, wtile: int):
    """One candidate-block step of the sweep with the window iterated in
    ``wtile``-column sub-blocks — the SHARED kernel body of the tiled TPU
    path and the GPU backend (gpu.py), which both hold the window in a
    ``(d_pad, W)`` / ``(1, W)`` ref pair revisited across the scan.

    Never materializes more than ``wtile * block_c`` test elements at
    once: the window test is a fori_loop over the live tiles (slots past
    ``count`` hold the sentinel and are inert, so any tile bound >= live
    is exact) and the append touches only the tiles its slot range
    [count, count+kept) intersects.  Keep decisions, slot assignment and
    count are bit-for-bit the untiled body's.  Returns the new count."""
    ntiles = wcap // wtile

    # (a) dominated by a live window member, one wtile-wide sub-block at
    # a time (same inertness argument as the untiled body: empty slots
    # hold the sentinel coordinate and cannot dominate data below it)
    live = jnp.minimum(
        (jnp.minimum(count, wcap) + wtile - 1) // wtile, ntiles)

    def wbody(t, acc):
        wt = pl.load(win_ref, (slice(None), pl.ds(t * wtile, wtile)))
        le = jnp.ones((wtile, block_c), jnp.bool_)
        lt = jnp.zeros((wtile, block_c), jnp.bool_)
        for k in range(d):
            wk = wt[k, :][:, None]   # (T, 1)
            xk = x[k, :][None, :]    # (1, BC)
            le = le & (wk <= xk)
            lt = lt | (wk < xk)
        return acc | jnp.any(le & lt, axis=0)

    domw = jax.lax.fori_loop(0, live, wbody,
                             jnp.zeros((block_c,), jnp.bool_))

    # (b) the in-block lower-triangular self-test (O(BC^2), tile-free)
    keep = xm & ~domw & ~_self_test(x, d=d, block_c=block_c)

    # (c) append: same scatter-free one-hot integer-bit copy as the
    # untiled body, but built per touched tile — kept candidates land in
    # slots [count, count+kept), so only tiles intersecting that range
    # are visited (none when the window already overflowed: lo == hi)
    ki = keep.astype(jnp.int32)
    rid = jax.lax.broadcasted_iota(jnp.int32, (block_c, block_c), 0)
    cid = jax.lax.broadcasted_iota(jnp.int32, (block_c, block_c), 1)
    prefix = jnp.sum(ki[:, None] & (rid <= cid), axis=0)     # (BC,) incl c
    pos = count + prefix - 1                                 # (BC,)
    kept = jnp.sum(ki)
    ibits = {4: jnp.int32, 2: jnp.int16, 1: jnp.int8}[
        jnp.dtype(x.dtype).itemsize]
    izero = jnp.zeros((), ibits)
    lo = jnp.minimum(count // wtile, ntiles)
    hi = jnp.minimum((count + kept + wtile - 1) // wtile, ntiles)

    def abody(t, carry):
        base = t * wtile
        slot = base + jax.lax.broadcasted_iota(
            jnp.int32, (block_c, wtile), 1)
        onehot = keep[:, None] & (pos[:, None] == slot)      # (BC, T)
        newrow = jnp.any(onehot, axis=0)                     # (T,)
        cur = pl.load(win_ref, (slice(None), pl.ds(base, wtile)))
        rows = []
        for k in range(d):
            xb = jax.lax.bitcast_convert_type(x[k, :], ibits)
            vals = jnp.sum(jnp.where(onehot, xb[:, None], izero), axis=0)
            row = jax.lax.bitcast_convert_type(vals, x.dtype)
            rows.append(jnp.where(newrow, row, cur[k, :]))
        pl.store(win_ref, (slice(None), pl.ds(base, wtile)),
                 jnp.stack(rows))
        curm = pl.load(wmask_ref, (slice(None), pl.ds(base, wtile)))
        pl.store(wmask_ref, (slice(None), pl.ds(base, wtile)),
                 curm | newrow[None, :].astype(jnp.int32))
        return carry

    jax.lax.fori_loop(lo, hi, abody, jnp.int32(0))
    return count + kept


def _sfs_sweep_kernel(cands_ref, mask_ref, win_ref, wmask_ref, count_ref,
                      *, d: int, block_c: int, wcap: int, wtile: int,
                      sentinel):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        win_ref[...] = jnp.full_like(win_ref, sentinel)
        wmask_ref[...] = jnp.zeros_like(wmask_ref)
        count_ref[0, 0] = jnp.int32(0)

    x = cands_ref[...]           # (D_PAD, BC)
    xm = mask_ref[0, :] > 0      # (BC,)
    count = count_ref[0, 0]      # () int32

    if wtile:  # window-tiled step: resident tests bounded at T x BC
        count_ref[0, 0] = _tiled_block_step(
            x, xm, count, win_ref, wmask_ref, d=d, block_c=block_c,
            wcap=wcap, wtile=wtile)
        return

    w = win_ref[...]             # (D_PAD, W)

    # (a) dominated by a live window member.  The whole resident window
    # is tested at once with NO validity mask: empty slots hold the
    # sentinel coordinate in every attribute and therefore cannot
    # dominate data below the sentinel (same inertness argument as the
    # jnp sweep — the caller controls all padding).
    le = jnp.ones((wcap, block_c), jnp.bool_)
    lt = jnp.zeros((wcap, block_c), jnp.bool_)
    for k in range(d):  # unrolled: d is a static 2..8
        wk = w[k, :][:, None]    # (W, 1)
        xk = x[k, :][None, :]    # (1, BC)
        le = le & (wk <= xk)
        lt = lt | (wk < xk)
    domw = jnp.any(le & lt, axis=0)  # (BC,)

    # (b) the in-block lower-triangular self-test (shared helper)
    keep = xm & ~domw & ~_self_test(x, d=d, block_c=block_c)  # (BC,)
    rid = jax.lax.broadcasted_iota(jnp.int32, (block_c, block_c), 0)
    cid = jax.lax.broadcasted_iota(jnp.int32, (block_c, block_c), 1)

    # (c) append: slot of candidate c is count + |kept earlier in block|.
    # The in-block prefix count is a (BC, BC) masked reduction (no cumsum
    # primitive needed on the lane axis), and the scatter is a one-hot
    # masked sum over the INTEGER BITS of the values — exactly one
    # non-zero contributor per slot, and integer addition is exact, so
    # the copy preserves every bit (including -0.0, which a float sum
    # would flip to +0.0).  Keeps past the window capacity match no slot
    # id and are dropped, mirroring the reference's `mode="drop"`
    # scatter.
    ki = keep.astype(jnp.int32)
    prefix = jnp.sum(ki[:, None] & (rid <= cid), axis=0)     # (BC,) incl c
    pos = count + prefix - 1                                 # (BC,)
    slot = jax.lax.broadcasted_iota(jnp.int32, (block_c, wcap), 1)
    onehot = keep[:, None] & (pos[:, None] == slot)          # (BC, W)
    newrow = jnp.any(onehot, axis=0)                         # (W,)
    ibits = {4: jnp.int32, 2: jnp.int16, 1: jnp.int8}[
        jnp.dtype(x.dtype).itemsize]
    izero = jnp.zeros((), ibits)
    for k in range(d):
        xb = jax.lax.bitcast_convert_type(x[k, :], ibits)    # (BC,)
        vals = jnp.sum(jnp.where(onehot, xb[:, None], izero), axis=0)
        row = jax.lax.bitcast_convert_type(vals, x.dtype)    # (W,)
        win_ref[k, :] = jnp.where(newrow, row, w[k, :])
    wmask_ref[0, :] = wmask_ref[0, :] | newrow.astype(jnp.int32)
    count_ref[0, 0] = count + jnp.sum(ki)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "wcap", "wtile", "sentinel", "interpret"))
def sfs_sweep_pallas(
    cands_t: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    block_c: int,
    wcap: int,
    sentinel: float,
    wtile: int = 0,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused SFS sweep over a batch of score-sorted partitions.

    Args:
      cands_t: (P * D_PAD, N) transposed candidates, each partition's rows
        presorted by a strictly monotone score with invalid rows holding
        the sentinel coordinate; N % block_c == 0.  Attribute rows past
        the true d are zero (inert for the comparisons, never extracted).
      mask: (P, N) int32 row validity (0 = padding / invalid).
      block_c: candidate block (grid step) size.
      wcap: window capacity in rows (a multiple of the dominance block by
        construction in the caller).
      sentinel: fill value for empty window slots.
      wtile: window tile width — 0 tests the whole window per step
        (resident O(wcap x block_c)); a divisor of ``wcap`` iterates the
        test/append over wtile-column sub-blocks (resident
        O(wtile x block_c), bit-identical; see `_tiled_block_step`).
      interpret: run the kernel body in interpret mode (CPU validation).

    Returns:
      ``(window_t (P * D_PAD, wcap), wmask (P, wcap) int32,
      count (P, 1) int32)`` — the packed per-partition skyline window in
      the same transposed layout, its validity mask, and the total number
      of kept (skyline) rows, which may exceed ``wcap`` under overflow.
    """
    pd_pad, n = cands_t.shape
    assert pd_pad % D_PAD == 0, pd_pad
    p = pd_pad // D_PAD
    assert mask.shape == (p, n), (mask.shape, p, n)
    assert n % block_c == 0, (n, block_c)
    assert wtile == 0 or wcap % wtile == 0, (wcap, wtile)
    d = D_PAD  # attribute rows are padded/inert; unroll over all of them

    grid = (p, n // block_c)
    kernel = functools.partial(_sfs_sweep_kernel, d=d, block_c=block_c,
                               wcap=wcap, wtile=wtile, sentinel=sentinel)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((D_PAD, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_c), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((D_PAD, wcap), lambda i, j: (i, 0)),
            pl.BlockSpec((1, wcap), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pd_pad, wcap), cands_t.dtype),
            jax.ShapeDtypeStruct((p, wcap), jnp.int32),
            jax.ShapeDtypeStruct((p, 1), jnp.int32),
        ],
        interpret=interpret,
    )(cands_t, mask)


def sweep_vmem_bytes(*, block_c: int, wcap: int, wtile: int = 0,
                     itemsize: int = 4) -> int:
    """Static per-grid-step VMEM footprint estimate for the sweep kernel.

    Counts the pipelined block I/O plus the materialized intermediates
    of one ``(partition, candidate-block)`` step: the window tests, the
    ``(BC, BC)`` intra-block self-tests, and the append routing one-hot.
    Untiled (``wtile=0``) the tests/one-hot span the whole window —
    ``(W, BC)`` / ``(BC, W)`` — the W x BC law; with ``wtile=T`` they
    span one T-column sub-block at a time, so the bound drops to T x BC
    (only the d_pad x W window buffer itself still scales with W).
    Booleans are counted at one byte; `broadcasted_iota` comparisons are
    treated as fused into their consumers (Mosaic lowers them lazily),
    so this is the data-carrying-tensor bound, in bytes. The static
    verifier (`repro.analysis`) gates every compiled configuration
    against it, which is what lets capacity/block changes land without
    re-deriving the tiling by hand."""
    weff = wcap if wtile <= 0 else min(wtile, wcap)
    io = (D_PAD * block_c + D_PAD * wcap) * itemsize \
        + (block_c + wcap + 1) * 4              # mask/wmask/count (int32)
    win_tests = 2 * weff * block_c              # le, lt (bool)
    self_tests = 2 * block_c * block_c          # le_s, lt_s (bool)
    append = block_c * weff                     # onehot (bool)
    return io + win_tests + self_tests + append
