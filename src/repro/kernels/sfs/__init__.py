from repro.kernels.sfs.kernel import D_PAD, sfs_sweep_pallas
from repro.kernels.sfs.ops import sfs_sweep
from repro.kernels.sfs.ref import sfs_sweep_perpair

__all__ = ["sfs_sweep", "sfs_sweep_pallas", "sfs_sweep_perpair", "D_PAD"]
