"""One-call entry for the fused local-phase SFS sweep.

:func:`sfs_sweep` runs the entire sorted Sort-Filter-Skyline scan for a
**batch of partitions** in one dispatch.  The contract (shared by every
implementation and property-tested bit-for-bit in
tests/test_sfs_kernel.py):

  inputs   (P, npad, d) partitions, each presorted by a strictly monotone
           score (SFS topological order) with invalid rows holding the
           sentinel coordinate, plus the (P, npad) validity mask;
           ``npad % block == 0``.
  output   per partition: the packed window holding the first ``wcap``
           skyline members in score order, its validity mask, and the
           total keep count (may exceed ``wcap`` — overflow drops extra
           tuples, never adds spurious ones).

Implementations (selected by the backend layer, repro.kernels.backend):

  * ``'pallas'``     — compiled Pallas TPU kernel (kernel.py): one grid
                       over (partition, candidate-block), window + count
                       resident on chip for the whole scan.
  * ``'interpret'``  — same kernel body, interpret mode (CPU validation).
  * ``'gpu'``        — Triton-lowered Pallas kernel (gpu.py): one program
                       per partition, candidate blocks walked in-kernel
                       (GPU grids are parallel, so the TPU's revisited-
                       output-block residency trick does not apply).
  * ``'gpu_interpret'`` — the GPU body in interpret mode (CI validation).
  * ``'jnp'``        — the single-dispatch blocked-jnp sweep below: ONE
                       ``lax.scan`` whose body fuses the window test,
                       the lower-triangular self-test and the append
                       into a single combined comparison per block,
                       vmapped over partitions.  Replaces the seed's
                       per-(window-block, candidate-block) dominance
                       kernel launches.
  * ``'perpair'``    — the seed per-pair scan (ref.py), kept as the
                       bit-for-bit oracle and benchmark baseline.

All implementations take a ``wtile`` window-tile width: 0 tests the
whole window per candidate block (resident O(wcap x block)); a divisor
of ``wcap`` iterates the test over wtile-row sub-blocks so the resident
footprint is O(wtile x block) at any capacity.  The tile only changes
the schedule — every (impl, wtile) pair is bit-for-bit identical and
property-tested against the per-pair reference.  The per-pair reference
itself ignores ``wtile`` (it is the tile-free oracle).

Sorting/padding lives one layer up (repro.core.sfs.local_skyline_batch),
so all implementations consume identical bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import KernelSpec, resolve_spec
from repro.kernels.sfs import kernel as _kernel
from repro.kernels.sfs import ref as _ref

__all__ = ["sfs_sweep"]


def _sweep_one_jnp(pts_s, mask_s, *, block: int, wcap: int, sentinel,
                   wtile: int = 0):
    """Fused jnp sweep of ONE sorted partition.

    One ``lax.scan`` whose body fuses the whole per-block step the
    per-pair reference spreads over many kernel dispatches:

      * the lower-triangular self-test and the test against the *first*
        window block are ONE combined comparison — the refs are
        ``concat(window[:block], x)`` under a single STATIC allow mask
        (all-true on the window rows, lower-triangular on the self
        rows).  The first window block is resident in the scan carry, so
        the common case (running skyline <= one block) runs a single
        fused comparison per step with no dynamic slicing and no
        per-pair dispatch plumbing;
      * no runtime validity masks are built or applied in the dominance
        tests at all: every invalid ref row — empty window slot, masked
        or padded candidate — holds the sentinel coordinate in all
        attributes by construction of this entry point, and a sentinel
        row cannot dominate data whose coordinates stay below the
        sentinel (1.7e38), so those rows are inert without masking.
        This removes ~2 * block^2 bools of mask traffic per step;
      * only the rare deeper window blocks (running skyline past
        ``block`` rows) take the inner dynamically-bounded loop, with
        the same work bound as the reference.

    With ``wtile > 0`` the scan body instead iterates the window test
    over wtile-row sub-blocks (self-test separate, no resident first
    window block), bounding every materialized comparison at
    O(wtile x block) elements — the jnp twin of the Pallas kernel's
    `_tiled_block_step`, for hosts where the untiled fused comparison
    would blow the XLA:CPU/GPU working set at huge capacities.

    Keep decisions are boolean-identical, so the output is bit-for-bit
    the per-pair reference's (including overflow behaviour).
    """
    npad, d = pts_s.shape
    nb = npad // block
    xs = pts_s.reshape(nb, block, d)
    xms = mask_s.reshape(nb, block)
    tri = (jnp.arange(block)[:, None] < jnp.arange(block)[None, :])
    # static: window rows always allowed (empty slots are sentinel-inert),
    # self rows only from strictly earlier (smaller-score) positions
    allow = jnp.concatenate([jnp.ones((block, block), jnp.bool_), tri])
    nwb_max = wcap // block

    window0 = jnp.full((wcap, d), sentinel, pts_s.dtype)
    wmask0 = jnp.zeros((wcap,), jnp.bool_)

    def append(window, wmask, wcount, x, keep):
        pos = wcount + jnp.cumsum(keep) - 1
        dest = jnp.where(keep & (pos < wcap), pos, wcap)
        window = window.at[dest].set(x, mode="drop")
        wmask = wmask.at[dest].set(True, mode="drop")
        return window, wmask, wcount + jnp.sum(keep)

    if nb == 1:
        # Single-block fast path (small inputs, the serving regime): the
        # window is empty, so the self-test alone decides membership
        # (invalid rows are sentinel-filled, hence inert as refs) — the
        # window tile is irrelevant here.
        x, xm = xs[0], xms[0]
        le = jnp.all(x[:, None, :] <= x[None, :, :], axis=-1)
        lt = jnp.any(x[:, None, :] < x[None, :, :], axis=-1)
        domin = jnp.any(le & lt & tri, axis=0)
        window, wmask, wcount = append(window0, wmask0, jnp.int32(0), x,
                                       xm & ~domin)
        return window, wmask, wcount.astype(jnp.int32)

    if wtile:
        # Window-tiled scan body: self-test separate, window test over
        # wtile-row sub-blocks of the LIVE window only (slots past the
        # count hold the sentinel and are inert, so any tile bound >=
        # live is exact — live is just the work bound).
        ntiles = wcap // wtile

        def tbody(carry, inp):
            window, wmask, wcount = carry
            x, xm = inp
            le = jnp.all(x[:, None, :] <= x[None, :, :], axis=-1)
            lt = jnp.any(x[:, None, :] < x[None, :, :], axis=-1)
            dom = jnp.any(le & lt & tri, axis=0)
            live = jnp.minimum(
                (jnp.minimum(wcount, wcap) + wtile - 1) // wtile, ntiles)

            def wbody(t, acc):
                wblk = jax.lax.dynamic_slice(window, (t * wtile, 0),
                                             (wtile, d))
                wle = jnp.all(wblk[:, None, :] <= x[None, :, :], axis=-1)
                wlt = jnp.any(wblk[:, None, :] < x[None, :, :], axis=-1)
                return acc | jnp.any(wle & wlt, axis=0)

            dom = jax.lax.fori_loop(0, live, wbody, dom)
            window, wmask, wcount = append(window, wmask, wcount, x,
                                           xm & ~dom)
            return (window, wmask, wcount), None

        (window, wmask, wcount), _ = jax.lax.scan(
            tbody, (window0, wmask0, jnp.int32(0)), (xs, xms))
        return window, wmask, wcount

    def body(carry, inp):
        window, wmask, wcount = carry
        x, xm = inp

        # (a)+(b) fused: dominated by the first window block OR by an
        # earlier (smaller-score) row of the own block — one comparison
        # under the static allow mask.  Testing window block 0
        # unconditionally is exact even before anything was appended:
        # empty slots hold the sentinel and cannot dominate.
        refs = jnp.concatenate([window[:block], x])
        le = jnp.all(refs[:, None, :] <= x[None, :, :], axis=-1)
        lt = jnp.any(refs[:, None, :] < x[None, :, :], axis=-1)
        dom = jnp.any(le & lt & allow, axis=0)

        # deeper active window blocks (running skyline > block rows):
        # same dynamic work bound as the reference
        nwb = jnp.minimum((wcount + block - 1) // block, nwb_max)

        def wbody(wb, acc):
            wblk = jax.lax.dynamic_slice(window, (wb * block, 0),
                                         (block, d))
            wle = jnp.all(wblk[:, None, :] <= x[None, :, :], axis=-1)
            wlt = jnp.any(wblk[:, None, :] < x[None, :, :], axis=-1)
            return acc | jnp.any(wle & wlt, axis=0)

        dom = jax.lax.fori_loop(1, jnp.maximum(nwb, 1), wbody, dom)
        # (c) append, in the same scan body
        window, wmask, wcount = append(window, wmask, wcount, x,
                                       xm & ~dom)
        return (window, wmask, wcount), None

    (window, wmask, wcount), _ = jax.lax.scan(
        body, (window0, wmask0, jnp.int32(0)), (xs, xms))
    return window, wmask, wcount


def _pack_transposed(pts_s, d_pad):
    """(P, npad, d) -> (P * d_pad, npad) transposed layout with zero-
    padded attribute rows: 0 <= 0 keeps `le` true and 0 < 0 keeps `lt`
    false, so padded attributes are inert in every comparison."""
    p, npad, d = pts_s.shape
    cands_t = jnp.zeros((p, d_pad, npad), pts_s.dtype)
    cands_t = cands_t.at[:, :d, :].set(jnp.swapaxes(pts_s, 1, 2))
    return cands_t.reshape(p * d_pad, npad)


def _sweep_pallas(pts_s, mask_s, *, block: int, wcap: int, wtile: int,
                  sentinel, interpret: bool):
    """Pack the sorted batch into the TPU kernel's transposed layout,
    run the one-grid sweep, and unpack."""
    p, npad, d = pts_s.shape
    if d > _kernel.D_PAD:
        raise ValueError(
            f"d={d} > {_kernel.D_PAD} not supported by the Pallas sweep; "
            f"use impl='jnp'")
    cands_t = _pack_transposed(pts_s, _kernel.D_PAD)
    mask2d = mask_s.astype(jnp.int32)
    win_t, wmask, count = _kernel.sfs_sweep_pallas(
        cands_t, mask2d, block_c=block, wcap=wcap, wtile=wtile,
        sentinel=float(sentinel), interpret=interpret)
    window = jnp.swapaxes(
        win_t.reshape(p, _kernel.D_PAD, wcap)[:, :d, :], 1, 2)
    return window, wmask > 0, count[:, 0]


def _sweep_gpu(pts_s, mask_s, *, block: int, wcap: int, wtile: int,
               sentinel, interpret: bool):
    """Pack for the GPU kernel (attribute rows padded to a multiple of
    D_PAD — no hard d cap), run one program per partition, unpack."""
    from repro.kernels.sfs import gpu as _gpu
    p, npad, d = pts_s.shape
    d_pad = -(-max(d, 1) // _kernel.D_PAD) * _kernel.D_PAD
    cands_t = _pack_transposed(pts_s, d_pad)
    mask2d = mask_s.astype(jnp.int32)
    win_t, wmask, count = _gpu.sfs_sweep_pallas_gpu(
        cands_t, mask2d, block_c=block, wcap=wcap, wtile=wtile,
        sentinel=float(sentinel), interpret=interpret)
    window = jnp.swapaxes(win_t.reshape(p, d_pad, wcap)[:, :d, :], 1, 2)
    return window, wmask > 0, count[:, 0]


def _normalize_wtile(wtile: int, wcap: int, block: int) -> int:
    """Static window-tile normalization, shared by every implementation:
    <= 0 means untiled; tiles are clamped to the window and must divide
    it — a non-divisor falls back to ``block`` (which divides ``wcap``
    by construction in every caller), or to untiled as the last resort.
    Any returned value is bit-identical to any other (the tile is pure
    schedule), so normalizing is always safe."""
    wtile = int(wtile)
    if wtile <= 0:
        return 0
    if wtile >= wcap:
        return wcap
    if wcap % wtile != 0:
        return block if wcap % block == 0 else 0
    return wtile


@functools.partial(
    jax.jit, static_argnames=("block", "wcap", "wtile", "sentinel", "spec"))
def sfs_sweep(
    pts_s: jnp.ndarray,
    mask_s: jnp.ndarray,
    *,
    block: int,
    wcap: int,
    sentinel: float,
    wtile: int = 0,
    spec: KernelSpec | str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused local-phase SFS sweep of a (P, npad, d) sorted batch.

    ``wtile`` is the window-tile width (0 = whole window resident; see
    the module docstring).  Returns ``(window (P, wcap, d), wmask
    (P, wcap) bool, count (P,) int32)``; see the module docstring for
    the contract.
    """
    if pts_s.ndim != 3 or mask_s.shape != pts_s.shape[:2]:
        raise ValueError(f"expected (P, npad, d)/(P, npad), got "
                         f"{pts_s.shape}/{mask_s.shape}")
    if pts_s.shape[1] % block != 0:
        raise ValueError(f"npad={pts_s.shape[1]} not a multiple of "
                         f"block={block}")
    spec = resolve_spec(spec)
    d = pts_s.shape[2]
    if spec.max_d is not None and d > spec.max_d:
        raise ValueError(
            f"d={d} > {spec.max_d} not supported by the {spec.name!r} "
            f"backend; use impl='jnp'")
    wtile = _normalize_wtile(wtile, wcap, block)
    if spec.sweep in ("pallas", "interpret"):
        return _sweep_pallas(pts_s, mask_s, block=block, wcap=wcap,
                             wtile=wtile, sentinel=sentinel,
                             interpret=spec.sweep == "interpret")
    if spec.sweep in ("gpu", "gpu_interpret"):
        return _sweep_gpu(pts_s, mask_s, block=block, wcap=wcap,
                          wtile=wtile, sentinel=sentinel,
                          interpret=spec.sweep == "gpu_interpret")
    if spec.sweep == "jnp":
        one = functools.partial(_sweep_one_jnp, block=block, wcap=wcap,
                                wtile=wtile, sentinel=sentinel)
    else:  # 'perpair' — the seed reference path (tile-free oracle)
        one = functools.partial(_ref.sfs_sweep_perpair, block=block,
                                wcap=wcap, sentinel=sentinel,
                                dominance_impl=spec.dominance)
    return jax.vmap(one)(pts_s, mask_s)
