"""Per-pair reference for the fused local-phase SFS sweep.

This is the seed ``block_sfs`` scan body, preserved verbatim: the blocked
dominance kernel is dispatched once per (window-block, candidate-block)
pair inside an XLA ``fori_loop`` — many tiny launches and a deep op graph,
exactly the overhead the fused sweep removes.  It serves two purposes:

  * the **bit-for-bit oracle** every sweep implementation is property-
    tested against (tests/test_sfs_kernel.py), and
  * the **benchmark baseline** of the ``local_phase`` suite
    (``impl='perpair'`` through the same one-call entry).

The contract is that of :func:`repro.kernels.sfs.ops.sfs_sweep`: inputs
are score-sorted, sentinel-filled, block-padded partitions; the output is
the packed window (first ``wcap`` skyline members in score order), its
validity mask, and the total keep count (which may exceed ``wcap`` under
overflow — extra tuples are dropped, never spurious ones added).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dominance import dominated_mask

__all__ = ["sfs_sweep_perpair"]


def sfs_sweep_perpair(pts_s: jnp.ndarray, mask_s: jnp.ndarray, *,
                      block: int, wcap: int, sentinel,
                      dominance_impl: str = "jnp"):
    """Seed per-pair SFS scan of ONE sorted partition.

    Args:
      pts_s: (npad, d) rows presorted by a strictly monotone score,
        invalid rows holding the sentinel coordinate; npad % block == 0.
      mask_s: (npad,) bool row validity, same order.
      block: dominance-test block size.
      wcap: window rows (capacity rounded up to ``block``).
      sentinel: fill value for empty window slots.
      dominance_impl: impl string for the pairwise dominance kernel.

    Returns:
      ``(window (wcap, d), wmask (wcap,) bool, count () int32)``.
    """
    npad, d = pts_s.shape
    nb = npad // block

    window0 = jnp.full((wcap, d), sentinel, pts_s.dtype)
    wmask0 = jnp.zeros((wcap,), jnp.bool_)

    if nb == 1:
        # Single-block fast path (small inputs, the serving regime): the
        # window is empty, so the lower-triangular self-test alone
        # decides membership.
        domin = dominated_mask(pts_s, pts_s, mask_s, lower_tri=True,
                               impl=dominance_impl)
        keep = mask_s & ~domin
        pos = jnp.cumsum(keep) - 1
        dest = jnp.where(keep & (pos < wcap), pos, wcap)
        window = window0.at[dest].set(pts_s, mode="drop")
        wmask = wmask0.at[dest].set(True, mode="drop")
        return window, wmask, jnp.sum(keep).astype(jnp.int32)

    def body(b, carry):
        window, wmask, wcount = carry
        x = jax.lax.dynamic_slice(pts_s, (b * block, 0), (block, d))
        xm = jax.lax.dynamic_slice(mask_s, (b * block,), (block,))

        # (a) dominated by the active window prefix (dynamic bound): one
        # dominance-kernel dispatch per live window block
        nwb = jnp.minimum((wcount + block - 1) // block, wcap // block)

        def wbody(wb, acc):
            wblk = jax.lax.dynamic_slice(window, (wb * block, 0),
                                         (block, d))
            wm = jax.lax.dynamic_slice(wmask, (wb * block,), (block,))
            return acc | dominated_mask(x, wblk, wm, impl=dominance_impl)

        domw = jax.lax.fori_loop(0, nwb, wbody,
                                 jnp.zeros((block,), jnp.bool_))
        # (b) dominated within the block by an earlier (smaller-score) row
        domin = dominated_mask(x, x, xm, lower_tri=True,
                               impl=dominance_impl)

        keep = xm & ~domw & ~domin
        pos = wcount + jnp.cumsum(keep) - 1
        dest = jnp.where(keep & (pos < wcap), pos, wcap)
        window = window.at[dest].set(x, mode="drop")
        wmask = wmask.at[dest].set(True, mode="drop")
        return window, wmask, wcount + jnp.sum(keep)

    window, wmask, wcount = jax.lax.fori_loop(
        0, nb, body, (window0, wmask0, jnp.int32(0)))
    return window, wmask, wcount
