"""GPU (Triton-lowered Pallas) backend for the fused SFS sweep.

Same kernel body, different grid contract.  The TPU kernel (kernel.py)
relies on the *sequential* TPU grid: the candidate-block index is an
inner grid dimension and the window/count live in revisited output
blocks.  GPU grids are parallel — programs may run in any order and
concurrently — so revisiting an output block across grid steps is not a
valid accumulator there.  This backend therefore launches ONE program
per partition (``grid=(P,)``) and walks the candidate blocks in an
in-kernel ``fori_loop``; the per-partition window/count refs are touched
by exactly one program, so the sequential read-modify-write the sweep
needs is safe.

The per-block step itself is the shared tiled body
(:func:`repro.kernels.sfs.kernel._tiled_block_step`): window test over
``wtile``-column sub-blocks, lower-triangular self-test, scatter-free
integer-bit append — bit-for-bit the TPU kernel's (and the per-pair
reference's) keep decisions, slot assignment and count.  The tiling/VMEM
contract holds unchanged: resident test intermediates are O(wtile x BC)
(``wtile=0`` is normalized to one whole-window tile by the caller), so
`sweep_vmem_bytes` bounds this backend too (read "VMEM" as the GPU's
shared-memory/register budget per program).

The attribute dimension is padded to ``d_pad`` rows (multiple of
``D_PAD``, zero-filled, inert in every comparison) instead of the TPU's
hard ``d <= D_PAD`` sublane cap — the per-backend ``max_d`` lives in the
backend registry (`repro.kernels.backend`).  CI has no GPU, so the
``gpu_interpret`` backend runs this exact body in interpret mode for
bitwise validation; on a real GPU runtime the same call compiles through
the Triton lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sfs.kernel import D_PAD, _tiled_block_step

__all__ = ["sfs_sweep_pallas_gpu"]


def _sfs_sweep_gpu_kernel(cands_ref, mask_ref, win_ref, wmask_ref,
                          count_ref, *, d: int, block_c: int, nblocks: int,
                          wcap: int, wtile: int, sentinel):
    win_ref[...] = jnp.full_like(win_ref, sentinel)
    wmask_ref[...] = jnp.zeros_like(wmask_ref)

    def cbody(j, count):
        x = pl.load(cands_ref, (slice(None), pl.ds(j * block_c, block_c)))
        xm = pl.load(mask_ref,
                     (slice(None), pl.ds(j * block_c, block_c)))[0, :] > 0
        return _tiled_block_step(x, xm, count, win_ref, wmask_ref, d=d,
                                 block_c=block_c, wcap=wcap, wtile=wtile)

    count_ref[0, 0] = jax.lax.fori_loop(0, nblocks, cbody, jnp.int32(0))


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "wcap", "wtile", "sentinel", "interpret"))
def sfs_sweep_pallas_gpu(
    cands_t: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    block_c: int,
    wcap: int,
    sentinel: float,
    wtile: int = 0,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused SFS sweep, one GPU program per partition.

    Same contract as :func:`repro.kernels.sfs.kernel.sfs_sweep_pallas`
    except the attribute row count of ``cands_t`` may be any multiple of
    ``D_PAD`` (wide d pads to the next multiple; extra rows are zero and
    inert).  ``wtile=0`` runs one whole-window tile.
    """
    pd_pad, n = cands_t.shape
    p = mask.shape[0]
    assert p > 0 and pd_pad % p == 0, (pd_pad, p)
    d_pad = pd_pad // p
    assert d_pad % D_PAD == 0, d_pad
    assert mask.shape == (p, n), (mask.shape, p, n)
    assert n % block_c == 0, (n, block_c)
    wtile = wtile or wcap   # the GPU body is always the tiled step
    assert wcap % wtile == 0, (wcap, wtile)

    kernel = functools.partial(
        _sfs_sweep_gpu_kernel, d=d_pad, block_c=block_c,
        nblocks=n // block_c, wcap=wcap, wtile=wtile, sentinel=sentinel)
    return pl.pallas_call(
        kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((d_pad, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d_pad, wcap), lambda i: (i, 0)),
            pl.BlockSpec((1, wcap), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pd_pad, wcap), cands_t.dtype),
            jax.ShapeDtypeStruct((p, wcap), jnp.int32),
            jax.ShapeDtypeStruct((p, 1), jnp.int32),
        ],
        interpret=interpret,
    )(cands_t, mask)
