"""The skylint rule set: repo invariants the AST layer enforces.

Each rule encodes one discipline the paper's dispatch/communication
analysis depends on. The checks themselves live in `repro.analysis.lint`;
this module is the single place describing WHAT each rule means, its
fix-hint, and where it applies — the README renders from the same
metadata.

Suppression: append ``# skylint: disable=R1`` (comma-separate several
ids) to the offending line, or put it on a comment-only line directly
above. Suppressions should carry a justification comment; the gate
reports them as suppressed, not as clean.
"""

from __future__ import annotations

import dataclasses

__all__ = ["Rule", "RULES", "HOT_PATHS", "KERNEL_INTERNALS",
           "KERNEL_SUBMODULES", "R2_SCOPES", "R6_SCOPES",
           "STATE_OPERANDS", "COMPAT_MODULE"]


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    rationale: str
    hint: str


RULES = {
    "R1": Rule(
        "R1", "no host syncs in jitted-reachable code",
        "A `.item()` / `int()/float()/bool()`-on-array / `np.asarray` / "
        "`.block_until_ready()` inside code reachable from a jitted "
        "entry point forces a device round-trip per dispatch — exactly "
        "the per-feed sync the fused streaming path exists to avoid.",
        "move the value into the jitted program (traced data), or hoist "
        "the read out of the hot path and defer it behind the dispatch "
        "(see SkylineStream._maybe_resolve: poll is_ready() and overlay "
        "the pending record in-program until the device delivers); if "
        "the sync is a considered cost, suppress with a justification "
        "comment."),
    "R2": Rule(
        "R2", "no eager per-item shaping in pack paths",
        "Padding or device_put-ing items one at a time inside a Python "
        "loop dispatches O(items) tiny programs and defeats the "
        "two-level bucketed pack (one dispatch per size bucket).",
        "route ragged items through the engine's bucketed pack "
        "(SkylineEngine._pack) — pad host-side into the bucket, ship "
        "once."),
    "R3": Rule(
        "R3", "kernel internals only via the backend registry",
        "Importing repro.kernels.sfs.* / repro.kernels.dominance.* "
        "internals directly pins a call site to one implementation; "
        "the backend registry (resolve_spec) is what lets 'auto' pick "
        "Pallas on TPU and the jnp reference elsewhere — and what new "
        "backends plug into.",
        "import resolve_spec / KernelSpec from repro.kernels.backend "
        "and call through the spec."),
    "R4": Rule(
        "R4", "shard_map/Mesh imports only through repro.compat",
        "jax.experimental.shard_map moved across JAX releases; "
        "repro/compat.py is the one shim that tracks it (and the "
        "mesh-construction API). A raw import elsewhere breaks one of "
        "the two supported JAX versions.",
        "from repro.compat import shard_map, make_mesh, set_mesh."),
    "R5": Rule(
        "R5", "no Python branching on traced values in core/ hot paths",
        "`if`/`while` on a traced scalar either fails to trace or — via "
        "a silent concretization — forces a host sync inside the fused "
        "program, serializing the pipeline the paper's cost model "
        "assumes is one dispatch.",
        "use jnp.where / jax.lax.cond / jax.lax.select on the traced "
        "value, or hoist the decision to a static (Python-int) "
        "configuration value."),
    "R6": Rule(
        "R6", "state-update factories must declare buffer donation",
        "A jitted factory whose program consumes a `state`/`leaves` "
        "operand (the repo's single-owner state-update convention) "
        "without `donate_argnums` compiles to an A/B copy: every "
        "dispatch holds input AND output buffers live, doubling the "
        "fleet's steady-state device bytes — the regression the "
        "Layer-2 HLO aliasing invariant exists to catch.",
        "return `jax.jit(run, donate_argnums=(0,)) if cfg.donate else "
        "jax.jit(run)` (or take a `donate` cache-key parameter); if "
        "the factory is a read-only overlay whose state operand is "
        "legitimately shared (finalize/snapshot views), suppress with "
        "a rationale comment — the suppression documents the "
        "ownership contract."),
}

# R1's second scope: serving-path methods that are NOT jit-reachable
# (they run host-side) but sit on the per-feed critical path, where a
# blocking device read serializes the dispatch pipeline all the same.
# NOT listed (the sanctioned blocking settles, never on a serving op's
# path): SkylineStream._force_resolve / drain — shutdown/test sync
# points only. `_wave_feed` stays in scope with no carve-out: a
# repeated overflow of a slot with a pending record in flight *chains*
# onto the live record list (every wave overlays all alive records
# in-program), so no serving code path retains a sanctioned blocking
# read.
HOT_PATHS = {
    "repro.serve.engine": {
        "SkylineStream.feed", "SkylineStream.tick",
        "SkylineStream.expire_epoch", "SkylineStream._promote",
        "SkylineStream.snapshot", "SkylineStream._maybe_resolve",
        "_wave_feed",
        "SkylineEngine.run", "SkylineEngine._run_stacked",
        "SkylineEngine.submit", "SkylineEngine.submit_many",
        "SkylineEngine.member_masks",
    },
    "repro.serve.loop": {
        "ServeLoop.submit", "ServeLoop.feed", "ServeLoop._stage_once",
        "ServeLoop._stage_loop", "ServeLoop._admit_locked",
    },
}

# R3: these packages' SUBMODULES are internal; their package __init__
# re-exports the sanctioned dispatcher entry points (which route through
# resolve_spec), so only submodule imports are violations — and only
# outside the kernels package itself.
KERNEL_INTERNALS = ("repro.kernels.sfs", "repro.kernels.dominance")
KERNEL_SUBMODULES = ("kernel", "ops", "ref")

# R2 applies where ragged request data is shaped for dispatch; model /
# checkpoint code legitimately pads in static per-layer loops.
R2_SCOPES = ("serve", "core", "data", "launch")

# R6 applies where the streaming/serving state-update factories live;
# train/checkpoint code manages its own (already donated) step states.
R6_SCOPES = ("core", "serve")
# first-parameter names marking a jitted inner function as a
# state-update program (the operand the single-owner protocol donates):
# `state` for SkylineState / WindowedSkylineState programs, `leaves`
# for slab-arena programs fed from SlabArena.leaves().
STATE_OPERANDS = ("state", "leaves")

# R4: the one module allowed to touch raw shard_map / mesh APIs.
COMPAT_MODULE = "repro.compat"
