"""Finding model + baseline file for the static verifier.

A `Finding` is one rule violation at one source location. Its *baseline
key* is ``(rule, path, snippet)`` — the stripped source line text rather
than the line number — so grandfathered findings survive unrelated edits
above them and go stale (forcing a baseline refresh) exactly when the
offending line itself changes.

The baseline file is JSON::

    {"version": 1,
     "findings": [{"rule": "R1", "path": "src/.../x.py",
                   "snippet": "bool(fits)"}]}

and is checked in next to the package (``baseline.json``); regenerate
with ``python -m repro.analysis --write-baseline`` after consciously
grandfathering a finding.
"""

from __future__ import annotations

import dataclasses
import json

__all__ = ["Finding", "load_baseline", "write_baseline"]

BASELINE_VERSION = 1


@dataclasses.dataclass
class Finding:
    """One rule violation at one source location."""
    rule: str      # rule id, e.g. "R1"
    path: str      # repo-relative file path
    line: int      # 1-based line number
    col: int       # 0-based column
    message: str   # what was found
    hint: str      # the rule's fix-hint
    snippet: str   # stripped source line (the baseline key component)
    suppressed: bool = False   # a `# skylint: disable=<rule>` covers it
    baselined: bool = False    # grandfathered by the baseline file

    @property
    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.snippet)

    @property
    def active(self) -> bool:
        """Counts toward the gate (not suppressed, not baselined)."""
        return not (self.suppressed or self.baselined)

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "hint": self.hint, "snippet": self.snippet,
                "suppressed": self.suppressed,
                "baselined": self.baselined}

    def __str__(self) -> str:
        tag = (" [suppressed]" if self.suppressed
               else " [baselined]" if self.baselined else "")
        return f"{self.rule} {self.path}:{self.line}: {self.message}{tag}"


def load_baseline(path: str) -> set[tuple[str, str, str]]:
    """Baseline keys from a baseline JSON file (empty set if absent)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return set()
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{data.get('version')!r}")
    return {(e["rule"], e["path"], e["snippet"])
            for e in data.get("findings", [])}


def write_baseline(findings, path: str) -> int:
    """Write the (deduplicated) keys of ``findings`` as the new baseline;
    returns the number of entries."""
    keys = sorted({f.key for f in findings})
    entries = [{"rule": r, "path": p, "snippet": s} for r, p, s in keys]
    with open(path, "w") as f:
        json.dump({"version": BASELINE_VERSION, "findings": entries},
                  f, indent=1)
        f.write("\n")
    return len(entries)
