"""Layer 2 — the compiled-program invariant checker.

Builds the skyline program suite (`repro.launch.cells`: the five
dry-run cells plus the verifier-only engine/tick/slab programs), traces
each to a jaxpr, optionally compiles it, and statically asserts the
structural invariants the paper's dispatch/communication analysis rests
on:

* **no host round-trips** — no callback / infeed / outfeed primitive
  anywhere in a jitted body (and none of the matching ops in the
  compiled HLO);
* **collective census** — every named-axis collective runs over the
  ``workers`` axis only (the merge tree); nothing ever reduces over
  ``queries``, and the all_gather count is independent of Q (the
  paper's merge-communication bound: per-query cost does not grow with
  the batch);
* **vmap bucket program is collective-free** — the engine's
  below-threshold path must stay pure data parallelism;
* **slab boundary shapes** — the slab feed program's inputs and outputs
  carry slot-rows / epoch-capacity leading state dims, never the full
  state capacity C (full-C tensors may exist INSIDE the chunk pipeline,
  but padding slots back to C across the program edge is exactly the
  regression `epoch_capacity` removed);
* **tree-merge boundary** — in ``merge='tree'`` cells no collective
  over ``workers`` may carry the flat merge's full p x C_loc union
  (every operand AND result stays O(capacity) rows), and the ppermute
  round count must equal ceil(log2(W)) exactly — the communication
  bound the hierarchical merge exists to provide;
* **VMEM cap** — the W x BC Pallas footprint estimate of every compiled
  configuration stays under the per-core cap
  (`repro.kernels.backend.vmem_estimate`);
* **in-place state updates** — every state-bearing serving cell
  (streaming insert, window tick, slab feed, coalesced wave) donates
  its state/arena operand (`SkyConfig.donate`), and the compiled HLO
  module must carry the matching ``input_output_alias`` entry for each
  memory-bearing state leaf: XLA silently dropping a may-alias turns
  the O(1)-memory in-place update back into an A/B copy, doubling the
  fleet's steady-state live bytes without any test failing;
* **compiled memory budget** — ``compiled.memory_analysis()`` peak live
  bytes (arguments + outputs + temps - aliased) of every cell stays
  under a per-cell cap, so an accidental donation regression (or a
  temp-buffer blow-up) fails CI rather than shipping.

Unlike Layer 1 this imports jax and traces real programs, so it runs
wherever the test suite runs (any device count >= 1: shard_map emits
its collectives into the jaxpr even over size-1 mesh axes).
"""

from __future__ import annotations

import collections
import re

__all__ = ["verify_programs", "iter_eqns", "collective_census",
           "DEFAULT_VMEM_CAP", "DEFAULT_MEM_CAP"]

DEFAULT_VMEM_CAP = 16 * 2 ** 20  # 16 MiB of VMEM per core (v4/v5 class)
# per-cell compiled peak-live-bytes budget: the verifier cells are
# smoke-sized (~5 MB peak today), so 64 MiB catches an order-of-
# magnitude regression (a dropped donation, a temp blow-up) with
# headroom for device-count / XLA-version drift
DEFAULT_MEM_CAP = 64 * 2 ** 20

# named-axis collectives (the merge tree's vocabulary)
COLLECTIVE_PRIMS = {"all_gather", "psum", "all_to_all", "ppermute",
                    "pmin", "pmax", "reduce_scatter", "all_reduce"}
# primitives that round-trip to the host from inside a jitted body
HOST_PRIMS = {"pure_callback", "io_callback", "callback",
              "debug_callback", "infeed", "outfeed"}
# the same discipline at the HLO level (send/recv appear for host
# transfers; cross-replica collective-permute is fine and excluded)
_HLO_HOST_RE = re.compile(
    r"\b(infeed|outfeed|send|recv)\b\s*[=(]|custom-call.*callback",
    re.IGNORECASE)
# input_output_alias entries in the HLO module header:
# ``{out_index}: (param_number, {}, may-alias)`` — the empty inner
# braces pin the match to whole-parameter aliases (our state leaves
# flatten to scalar-arity params), so nested layout braces elsewhere in
# the header can't false-positive
_HLO_ALIAS_RE = re.compile(
    r"\{[0-9, ]*\}:\s*\((\d+),\s*\{\},\s*(?:may|must)-alias\)")
# cells whose argument 0 is the donated state/arena pytree
_DONATED_KINDS = {"stream", "window", "wtick", "slab_feed", "slab_wave"}
# XLA's buffer assignment may legitimately drop the alias slot of a
# tiny counter leaf (it fuses or rematerialises them); the in-place
# invariant is about the memory-bearing buffers (points/mask), so only
# leaves at least this large must keep their alias
_ALIAS_MIN_BYTES = 1024


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------

def _sub_jaxprs(params):
    """Nested (Closed)Jaxprs inside an eqn's params, duck-typed so the
    walk survives jax version drift."""
    for v in params.values():
        for x in (v if isinstance(v, (list, tuple)) else (v,)):
            j = getattr(x, "jaxpr", x)
            if hasattr(j, "eqns"):
                yield j


def iter_eqns(jaxpr):
    """Every eqn in ``jaxpr``, recursively (pjit/shard_map/scan/cond
    bodies included)."""
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            yield eqn
            stack.extend(_sub_jaxprs(eqn.params))


def _axis_names(params) -> list[str]:
    names = []
    for key in ("axis_name", "axes", "axis_index_groups_axis"):
        v = params.get(key)
        if v is None:
            continue
        for a in (v if isinstance(v, (tuple, list)) else (v,)):
            if isinstance(a, str):
                names.append(a)
    return names


def collective_census(closed_jaxpr):
    """{prim_name: {axis_tuple: count}} over the whole program, plus the
    list of host primitives found."""
    census: dict[str, collections.Counter] = collections.defaultdict(
        collections.Counter)
    host = []
    for eqn in iter_eqns(closed_jaxpr.jaxpr):
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            census[name][tuple(sorted(_axis_names(eqn.params)))] += 1
        elif name in HOST_PRIMS:
            host.append(name)
    return {k: dict(v) for k, v in census.items()}, host


def _boundary_dims(closed_jaxpr) -> set[int]:
    """Every dimension size crossing the program edge (in/out avals)."""
    dims: set[int] = set()
    for v in list(closed_jaxpr.jaxpr.invars) + \
            list(closed_jaxpr.jaxpr.outvars):
        shape = getattr(getattr(v, "aval", None), "shape", ())
        dims.update(int(s) for s in shape)
    return dims


# --------------------------------------------------------------------------
# the verification pass
# --------------------------------------------------------------------------

def _check_cell(name, spec, built, *, vmem_cap, mem_cap, compile_hlo,
                errors, record):
    import jax

    closed = jax.make_jaxpr(built.fn)(*built.argspecs)
    census, host = collective_census(closed)
    record.update(collectives={p: {"+".join(a) or "<positional>": c
                                   for a, c in v.items()}
                               for p, v in census.items()},
                  host_prims=host)

    if host:
        errors.append(f"{name}: host primitives in jitted body: {host}")
    axes = {a for v in census.values() for t in v for a in t}
    if axes - {"workers"}:
        errors.append(f"{name}: collectives over non-worker axes "
                      f"{sorted(axes - {'workers'})} — merges must stay "
                      f"on the workers axis")
    if built.kind == "vmap_batch" and census:
        errors.append(f"{name}: the vmap bucket program must be "
                      f"collective-free, found {sorted(census)}")

    if built.kind in ("slab_feed", "slab_wave"):
        from repro.core.incremental import state_capacity
        c = state_capacity(built.cfg)
        dims = _boundary_dims(closed)
        record["boundary_dims"] = sorted(dims)
        if built.info["epoch_cap"] < c and c in dims:
            errors.append(
                f"{name}: full state capacity C={c} crosses the slab "
                f"{'wave' if built.kind == 'slab_wave' else 'feed'} "
                f"program edge — slots must stay at their "
                f"rows/epoch_capacity shapes")

    if getattr(built.cfg, "merge", "flat") == "tree" \
            and built.mesh is not None:
        from repro.core.incremental import state_capacity
        from repro.core.parallel import merge_rounds
        w = int(dict(built.mesh.shape).get("workers", 1))
        rounds = merge_rounds(w)
        nperm = census.get("ppermute", {}).get(("workers",), 0)
        record["tree_rounds"] = {"expected": rounds, "ppermute": nperm}
        if nperm != rounds:
            errors.append(
                f"{name}: tree merge must run exactly ceil(log2({w})) ="
                f" {rounds} ppermute rounds over workers, found {nperm}")
        # no workers-collective may carry the flat merge's p x C_loc
        # union: operands AND results stay O(capacity) rows (the wire
        # packs points + mask + noseq side columns, and buffers briefly
        # sit at 2 x capacity rows in-round — 4 x C x (d+2) elements
        # bounds all of that with headroom while sitting orders of
        # magnitude below the p-proportional union)
        c = state_capacity(built.cfg)
        bound = 4 * c * (built.info["d"] + 2)
        worst = 0
        for eqn in iter_eqns(closed.jaxpr):
            if eqn.primitive.name in COLLECTIVE_PRIMS \
                    and "workers" in _axis_names(eqn.params):
                for v in list(eqn.invars) + list(eqn.outvars):
                    shape = getattr(getattr(v, "aval", None), "shape", ())
                    sz = 1
                    for s in shape:
                        sz *= int(s)
                    worst = max(worst, sz)
        record["tree_boundary"] = {"bound": bound, "max_operand": worst}
        if worst > bound:
            errors.append(
                f"{name}: a workers collective carries {worst} elements"
                f" — above the tree-merge boundary bound {bound} "
                f"(O(capacity), independent of p); the flat union must "
                f"never ride a tree-mode program")

    # Q-independence: double the batch (for the serve-loop wave cell:
    # the coalesced wave size), the merge collectives must not multiply
    # (per-query communication is Q-independent)
    if built.kind in ("batch", "stream", "window", "slab_wave") \
            and census:
        from repro.launch.cells import SKYLINE_CELLS, build_skyline_cell
        spec2 = dict(spec, q=spec["q"] * 2)
        built2 = build_skyline_cell(name, spec2,
                                    smoke=name in SKYLINE_CELLS,
                                    max_devices=len(jax.devices()))
        census2, _ = collective_census(
            jax.make_jaxpr(built2.fn)(*built2.argspecs))
        n1 = sum(c for v in census.values() for c in v.values())
        n2 = sum(c for v in census2.values() for c in v.values())
        record["collective_count_q"] = n1
        record["collective_count_2q"] = n2
        if n1 != n2:
            errors.append(
                f"{name}: collective count changed {n1} -> {n2} when Q "
                f"doubled — merge communication must be Q-independent")

    # the Pallas footprint of this configuration at its window tile
    # (untiled: W x BC resident; tiled: wtile x BC — the tile is what
    # lets large capacities hold the cap)
    from repro.kernels.backend import vmem_estimate
    est = vmem_estimate(built.cfg.block, built.cfg.capacity,
                        wtile=built.cfg.wtile)
    record["vmem"] = est
    for fam in ("sweep", "dominance"):
        if est[fam] > vmem_cap:
            errors.append(
                f"{name}: {fam} kernel VMEM estimate {est[fam]} B "
                f"exceeds the {vmem_cap} B cap at block="
                f"{built.cfg.block}, W={est['window_rows']}, "
                f"wtile={est['window_tile']}")

    if compile_hlo:
        import math

        compiled = built.fn.lower(*built.argspecs).compile()
        text = compiled.as_text()
        hits = sorted({m.group(1) or "callback"
                       for m in _HLO_HOST_RE.finditer(text)})
        record["hlo_host_ops"] = hits
        if hits:
            errors.append(f"{name}: host-transfer ops in compiled HLO: "
                          f"{hits}")

        # in-place update invariant: the donated state/arena operand
        # must survive compilation as real input->output aliases in the
        # module header (alias entries only ever appear there)
        if built.kind in _DONATED_KINDS \
                and getattr(built.cfg, "donate", True):
            aliased = {int(m.group(1))
                       for m in _HLO_ALIAS_RE.finditer(
                           text.splitlines()[0])}
            leaves = jax.tree.leaves(built.argspecs[0])
            need = [i for i, leaf in enumerate(leaves)
                    if math.prod(leaf.shape)
                    * jax.numpy.dtype(leaf.dtype).itemsize
                    >= _ALIAS_MIN_BYTES]
            record["donated_aliasing"] = {
                "aliased_params": sorted(aliased),
                "required_params": need}
            missing = [i for i in need if i not in aliased]
            if missing:
                errors.append(
                    f"{name}: donated state params {missing} carry no "
                    f"input_output_alias in the compiled HLO — XLA "
                    f"dropped the donation and the state update is an "
                    f"A/B copy again")

        # compiled memory budget: peak live bytes = everything resident
        # while the program runs, minus the donated bytes the outputs
        # reuse — the number the feed_memory benchmark measures live
        mem = compiled.memory_analysis()
        if mem is not None:
            stats = {k: int(getattr(mem, f"{k}_size_in_bytes", 0) or 0)
                     for k in ("argument", "output", "temp", "alias")}
            stats["peak"] = (stats["argument"] + stats["output"]
                             + stats["temp"] - stats["alias"])
            record["memory"] = stats
            if stats["peak"] > mem_cap:
                errors.append(
                    f"{name}: compiled peak live bytes {stats['peak']} "
                    f"exceed the {mem_cap} B per-cell budget "
                    f"(argument={stats['argument']} output="
                    f"{stats['output']} temp={stats['temp']} "
                    f"alias={stats['alias']})")


def verify_programs(names=None, *, vmem_cap: int = DEFAULT_VMEM_CAP,
                    mem_cap: int = DEFAULT_MEM_CAP,
                    compile_hlo: bool = True):
    """Verify the program suite; returns ``(report: dict, errors:
    list[str])`` — empty ``errors`` means every invariant holds.

    ``names`` restricts the suite; dry-run cells build in smoke size
    (the invariants are size-independent), verifier-only cells at their
    declared (already small) sizes."""
    import jax

    from repro.launch.cells import (SKYLINE_CELLS, VERIFIER_EXTRA_CELLS,
                                    build_skyline_cell)
    suite = {**SKYLINE_CELLS, **VERIFIER_EXTRA_CELLS}
    if names:
        unknown = set(names) - set(suite)
        if unknown:
            raise ValueError(f"unknown cells {sorted(unknown)}; "
                             f"have {sorted(suite)}")
        suite = {k: v for k, v in suite.items() if k in names}
    ndev = len(jax.devices())
    report: dict = {"devices": ndev, "vmem_cap": vmem_cap,
                    "mem_cap": mem_cap, "cells": {}}
    errors: list[str] = []
    for name, spec in suite.items():
        built = build_skyline_cell(name, spec,
                                   smoke=name in SKYLINE_CELLS,
                                   max_devices=ndev)
        record: dict = {"kind": built.kind, "mesh": built.info.get("mesh")}
        report["cells"][name] = record
        try:
            _check_cell(name, spec, built, vmem_cap=vmem_cap,
                        mem_cap=mem_cap, compile_hlo=compile_hlo,
                        errors=errors, record=record)
        except Exception as e:  # a cell failing to build IS a finding
            errors.append(f"{name}: {type(e).__name__}: {e}")
            record["error"] = f"{type(e).__name__}: {e}"
    return report, errors
