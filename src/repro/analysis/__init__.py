"""Static verification of the dispatch/communication discipline.

Two layers gate the invariants the paper's parallel-skyline cost model
assumes (one dispatch per feed, merge communication bounded on the
workers axis):

* Layer 1, **skylint** (`repro.analysis.lint`) — pure-AST rules R1–R5
  over ``src/repro``: no host syncs in jitted-reachable code, no
  per-item shaping loops in pack paths, kernel call sites through the
  backend registry, shard_map/Mesh only via `repro.compat`, no Python
  branching on traced values in ``core/``. No jax import; runs anywhere.
* Layer 2, **program verifier** (`repro.analysis.verifier`) — traces
  the skyline program suite (`repro.launch.cells`) and walks
  jaxpr/HLO: no host callbacks, workers-only collective census,
  Q-independent merge communication, slab boundary-shape census, and
  the W x BC Pallas VMEM bound per configuration.

CLI: ``python -m repro.analysis`` (JSON report, non-zero exit on any
active finding) — the blocking CI gate. Rules, suppression syntax, and
the baseline workflow are documented in ``src/repro/analysis/README.md``.

This module imports only the jax-free layer; import
`repro.analysis.verifier` explicitly for Layer 2.
"""

from repro.analysis.findings import Finding, load_baseline, write_baseline
from repro.analysis.lint import lint_paths
from repro.analysis.rules import RULES

__all__ = ["Finding", "RULES", "lint_paths", "load_baseline",
           "write_baseline"]
