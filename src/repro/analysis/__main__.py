"""CLI for the static verifier: ``python -m repro.analysis``.

Exit codes: 0 clean, 1 active findings / failed invariants, 2 usage or
internal error. ``--json`` writes the full machine-readable report (the
CI artifact); findings always print human-readable to stdout.

Environment handling mirrors the dry-run harness: ``--devices N``
forces N host devices via XLA_FLAGS — parsed and applied BEFORE jax is
imported (Layer 2 imports jax lazily for exactly this reason); Layer 1
never imports jax at all.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    default_root = os.path.abspath(os.path.join(here, "..", "..", ".."))
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verifier: AST lint (skylint) + compiled-"
                    "program invariant checks")
    ap.add_argument("--layer", choices=("lint", "verify", "all"),
                    default="all")
    ap.add_argument("--paths", nargs="*", default=None,
                    help="files/dirs for the lint layer "
                         "(default: src/repro)")
    ap.add_argument("--cells", nargs="*", default=None,
                    help="restrict the verify layer to these cells")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write the full JSON report here ('-' = stdout)")
    ap.add_argument("--baseline", default=os.path.join(here,
                                                       "baseline.json"))
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current lint findings as the baseline")
    ap.add_argument("--devices", type=int, default=None,
                    help="force N host devices for the verify layer")
    ap.add_argument("--vmem-cap", type=int, default=None,
                    help="per-core VMEM cap in bytes (default 16 MiB)")
    ap.add_argument("--mem-cap", type=int, default=None,
                    help="per-cell compiled peak-live-bytes budget "
                         "(default 64 MiB)")
    ap.add_argument("--no-compile", action="store_true",
                    help="skip the HLO-level pass (jaxpr walk only)")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.devices}")
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    report: dict = {"layers": {}}
    failed = False

    if args.layer in ("lint", "all"):
        from repro.analysis.findings import load_baseline, write_baseline
        from repro.analysis.lint import lint_paths
        paths = args.paths or [os.path.join(default_root, "src", "repro")]
        findings = lint_paths(paths, repo_root=default_root,
                              baseline_keys=load_baseline(args.baseline))
        if args.write_baseline:
            n = write_baseline([f for f in findings if not f.suppressed],
                               args.baseline)
            print(f"baseline: wrote {n} entries to {args.baseline}")
            for f in findings:
                f.baselined = not f.suppressed
        active = [f for f in findings if f.active]
        for f in findings:
            print(f)
            if f.active:
                print(f"    hint: {f.hint}")
        report["layers"]["lint"] = {
            "findings": [f.to_json() for f in findings],
            "active": len(active)}
        print(f"skylint: {len(findings)} finding(s), "
              f"{len(active)} active")
        failed |= bool(active)

    if args.layer in ("verify", "all"):
        from repro.analysis.verifier import (DEFAULT_MEM_CAP,
                                             DEFAULT_VMEM_CAP,
                                             verify_programs)
        vreport, errors = verify_programs(
            args.cells, vmem_cap=args.vmem_cap or DEFAULT_VMEM_CAP,
            mem_cap=args.mem_cap or DEFAULT_MEM_CAP,
            compile_hlo=not args.no_compile)
        vreport["errors"] = errors
        report["layers"]["verify"] = vreport
        for e in errors:
            print(f"VERIFY {e}")
        print(f"verifier: {len(vreport['cells'])} program(s), "
              f"{len(errors)} invariant violation(s)")
        failed |= bool(errors)

    report["ok"] = not failed
    if args.json == "-":
        json.dump(report, sys.stdout, indent=1, default=str)
        print()
    elif args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, default=str)
        print(f"report: {args.json}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
