"""skylint — the AST layer of the static verifier (no jax import).

Pure-`ast` analysis over `src/repro`: it never imports the code under
inspection, so it runs in milliseconds, before any device runtime
exists, on any host.

Pipeline:

1. collect every module's functions, their *loaded names* (an
   over-approximate callee set: bare `Name` loads plus `Attribute`
   tails), per-line suppressions, and the jitted entry points
   (``jax.jit(f)`` targets and ``@jax.jit`` /
   ``@functools.partial(jax.jit, ...)`` decorations);
2. build the repo-wide bare-name call graph and mark everything
   reachable from a jitted entry point;
3. run rules R1–R6 (`repro.analysis.rules`) over their scopes.

The bare-name reachability is deliberately an over-approximation (a
loaded name reaches EVERY function of that name anywhere in the tree):
for a lint gate, a false reachability edge at worst surfaces a finding
a human then suppresses with a recorded justification; a missed edge
would silently wave a host sync through.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re

from repro.analysis.findings import Finding
from repro.analysis.rules import (COMPAT_MODULE, HOT_PATHS,
                                  KERNEL_INTERNALS, KERNEL_SUBMODULES,
                                  R2_SCOPES, R6_SCOPES, RULES,
                                  STATE_OPERANDS)

__all__ = ["lint_paths", "collect_module", "ModuleInfo", "FunctionInfo"]

_SUPPRESS_RE = re.compile(r"#\s*skylint:\s*disable=([A-Za-z0-9,\s]+)")

# host-sync attribute calls (R1)
_SYNC_ATTRS = {"item", "block_until_ready"}
# numpy-conversion callees (R1, jit-reachable scope only)
_NP_FUNCS = {("np", "asarray"), ("np", "array"),
             ("numpy", "asarray"), ("numpy", "array")}
# roots marking an expression as traced-array-producing
_ARRAY_ROOTS = {"jnp", "jax", "lax"}


# --------------------------------------------------------------------------
# collection
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FunctionInfo:
    qualname: str          # e.g. "SkylineStream.feed"
    name: str              # bare name, the call-graph key
    node: ast.AST
    module: "ModuleInfo"
    loaded: set[str]       # Name loads + Attribute tails in the body
    is_root: bool = False  # jitted entry point


@dataclasses.dataclass
class ModuleInfo:
    path: str              # repo-relative path (finding location)
    modname: str           # dotted name ("repro.serve.engine")
    tree: ast.Module
    lines: list[str]
    suppressions: dict[int, set[str]]   # 1-based line -> rule ids
    functions: list[FunctionInfo] = dataclasses.field(default_factory=list)
    # bare jit-target names with no lexically resolvable definition
    # (lambda bodies, cross-module references)
    root_names: set[str] = dataclasses.field(default_factory=set)
    # (enclosing scope stack, target name) of each jax.jit(...) call,
    # resolved lexically in `_reachable`
    root_refs: list = dataclasses.field(default_factory=list)


def _dotted(node) -> str | None:
    """'jax.experimental.shard_map' for a Name/Attribute chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit(node) -> bool:
    return _dotted(node) in ("jit", "jax.jit")


def _is_partial(node) -> bool:
    return _dotted(node) in ("partial", "functools.partial")


def _loaded_names(node) -> set[str]:
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out


def _jit_targets(call: ast.Call) -> set[str]:
    """Bare names a ``jax.jit(...)`` call turns into entry points."""
    if not call.args:
        return set()
    arg = call.args[0]
    if isinstance(arg, ast.Name):
        return {arg.id}
    if isinstance(arg, ast.Call) and _is_partial(arg.func) and arg.args:
        inner = arg.args[0]
        if isinstance(inner, ast.Name):
            return {inner.id}
    if isinstance(arg, ast.Lambda):
        return _loaded_names(arg.body)
    return set()


def _suppressions(lines: list[str]) -> dict[int, set[str]]:
    """Per-line suppressed rules; a comment-only suppression line also
    covers the line below it."""
    out: dict[int, set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = {r.strip().upper() for r in m.group(1).split(",")
                 if r.strip()}
        out.setdefault(i, set()).update(rules)
        if text.lstrip().startswith("#"):  # comment-only: covers below
            out.setdefault(i + 1, set()).update(rules)
    return out


class _FnCollector(ast.NodeVisitor):
    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.stack: list[str] = []

    def _visit_fn(self, node):
        qual = ".".join(self.stack + [node.name])
        info = FunctionInfo(qual, node.name, node, self.mod,
                            _loaded_names(node))
        for dec in node.decorator_list:
            if _is_jit(dec):
                info.is_root = True
            elif (isinstance(dec, ast.Call)
                  and (_is_jit(dec.func)
                       or (_is_partial(dec.func) and dec.args
                           and _is_jit(dec.args[0])))):
                info.is_root = True
        self.mod.functions.append(info)
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_ClassDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def visit_Call(self, node):
        if _is_jit(node.func):
            self.mod.root_refs.append((tuple(self.stack),
                                       _jit_targets(node)))
        self.generic_visit(node)


def _modname(path: str, repo_root: str) -> str:
    rel = os.path.relpath(path, repo_root)
    parts = rel.replace(os.sep, "/").removesuffix(".py").split("/")
    if "repro" in parts:  # real tree: dotted from the package root
        parts = parts[parts.index("repro"):]
    elif parts and parts[0] in ("src", "."):
        parts = parts[1:]
    return ".".join(p for p in parts if p not in ("", "."))


def collect_module(path: str, repo_root: str) -> ModuleInfo:
    with open(path) as f:
        source = f.read()
    lines = source.splitlines()
    mod = ModuleInfo(path=os.path.relpath(path, repo_root),
                     modname=_modname(path, repo_root),
                     tree=ast.parse(source, filename=path),
                     lines=lines, suppressions=_suppressions(lines))
    _FnCollector(mod).visit(mod.tree)
    return mod


# --------------------------------------------------------------------------
# reachability
# --------------------------------------------------------------------------

def _reachable(mods: list[ModuleInfo]) -> set[int]:
    """ids of FunctionInfos reachable from any jitted entry point.

    jax.jit(target) references resolve LEXICALLY first — innermost
    enclosing scope outward, then module level — so the ubiquitous
    factory pattern (``def _x_fn(...): def run(...): ...; return
    jax.jit(run)``) seeds exactly its own nested ``run``, not every
    function of that name in the tree. Only unresolvable targets
    (lambdas, cross-module names) fall back to bare-name seeding."""
    by_name: dict[str, list[FunctionInfo]] = {}
    for m in mods:
        for fn in m.functions:
            by_name.setdefault(fn.name, []).append(fn)
    seeds: list[FunctionInfo] = []
    root_names: set[str] = set()
    for m in mods:
        root_names |= m.root_names
        by_qual = {fn.qualname: fn for fn in m.functions}
        for scope, names in m.root_refs:
            for name in names:
                for i in range(len(scope), -1, -1):
                    fn = by_qual.get(".".join((*scope[:i], name)))
                    if fn is not None:
                        seeds.append(fn)
                        break
                else:
                    root_names.add(name)
    queue = seeds + [fn for m in mods for fn in m.functions
                     if fn.is_root or fn.name in root_names]
    seen: set[int] = set()
    while queue:
        fn = queue.pop()
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        for name in fn.loaded:
            for g in by_name.get(name, ()):
                if id(g) not in seen:
                    queue.append(g)
    return seen


# --------------------------------------------------------------------------
# per-rule checks
# --------------------------------------------------------------------------

def _finding(rule: str, mod: ModuleInfo, node, message: str) -> Finding:
    line = getattr(node, "lineno", 1)
    text = mod.lines[line - 1].strip() if line <= len(mod.lines) else ""
    return Finding(rule=rule, path=mod.path, line=line,
                   col=getattr(node, "col_offset", 0),
                   message=message, hint=RULES[rule].hint, snippet=text)


def _has_array_call(node) -> bool:
    """Does the subtree contain a call rooted at jnp/jax/lax?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            d = _dotted(sub.func)
            if d and d.split(".")[0] in _ARRAY_ROOTS:
                return True
    return False


def _local_call_bindings(fn_node) -> dict[str, ast.Call]:
    """name -> the Call expression it was (tuple-)assigned from."""
    out: dict[str, ast.Call] = {}
    for sub in ast.walk(fn_node):
        if not isinstance(sub, ast.Assign):
            continue
        if not isinstance(sub.value, ast.Call):
            continue
        for tgt in sub.targets:
            elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
            for e in elts:
                if isinstance(e, ast.Name):
                    out[e.id] = sub.value
    return out


def _device_producing(call: ast.Call, bindings: dict[str, ast.Call],
                      depth: int = 0) -> bool:
    """Does this call plausibly return a device array? True for
    ``something_fn(...)``, jnp/jax-rooted calls, calls of calls
    (``factory(...)(...)``), and calls through a local name bound from
    such a call."""
    if depth > 4:
        return False
    func = call.func
    d = _dotted(func)
    if d:
        leaf = d.split(".")[-1]
        if leaf.endswith("_fn") or d.split(".")[0] in _ARRAY_ROOTS:
            return True
        if d in bindings:
            return _device_producing(bindings[d], bindings, depth + 1)
        return False
    if isinstance(func, ast.Call):  # factory(...)(...)
        return True
    return False


def _check_sync_calls(fn: FunctionInfo, *, numpy_too: bool,
                      out: list[Finding]) -> None:
    mod = fn.module
    bindings = _local_call_bindings(fn.node)
    for sub in ast.walk(fn.node):
        if not isinstance(sub, ast.Call):
            continue
        func = sub.func
        if isinstance(func, ast.Attribute) and func.attr in _SYNC_ATTRS:
            out.append(_finding(
                "R1", mod, sub,
                f".{func.attr}() blocks on the device inside "
                f"{fn.qualname}"))
            continue
        d = _dotted(func)
        if numpy_too and d and tuple(d.split(".", 1)) in _NP_FUNCS:
            out.append(_finding(
                "R1", mod, sub,
                f"{d}() copies device->host inside jit-reachable "
                f"{fn.qualname}"))
            continue
        if (isinstance(func, ast.Name)
                and func.id in ("int", "float", "bool") and sub.args):
            arg = sub.args[0]
            arrayish = _has_array_call(arg) or (
                isinstance(arg, ast.Name) and arg.id in bindings
                and _device_producing(bindings[arg.id], bindings))
            if arrayish:
                out.append(_finding(
                    "R1", mod, sub,
                    f"{func.id}() on a device value syncs the host "
                    f"inside {fn.qualname}"))


def _check_r1(mods, reachable, out) -> None:
    for m in mods:
        hot = HOT_PATHS.get(m.modname, set())
        for fn in m.functions:
            if id(fn) in reachable:
                _check_sync_calls(fn, numpy_too=True, out=out)
            elif fn.qualname in hot:
                _check_sync_calls(fn, numpy_too=False, out=out)


_LOOPS = (ast.For, ast.AsyncFor, ast.While, ast.ListComp, ast.SetComp,
          ast.DictComp, ast.GeneratorExp)
_R2_CALLS = {"jnp.pad", "jax.device_put", "device_put"}


def _check_r2(mods, out) -> None:
    for m in mods:
        if not any(_in_scope(m.modname, f"repro.{leaf}")
                   for leaf in R2_SCOPES):
            continue
        for loop in ast.walk(m.tree):
            if not isinstance(loop, _LOOPS):
                continue
            for sub in ast.walk(loop):
                if isinstance(sub, ast.Call) \
                        and _dotted(sub.func) in _R2_CALLS:
                    out.append(_finding(
                        "R2", m, sub,
                        f"per-item {_dotted(sub.func)}() inside a loop "
                        f"— ragged items must go through the bucketed "
                        f"pack"))


def _in_scope(modname: str, dotted_pkg: str) -> bool:
    """modname is dotted_pkg or inside it (by dotted-path containment,
    so fixture trees like 'core.hot' scope like 'repro.core.hot')."""
    pad = f".{modname}."
    return f".{dotted_pkg.split('.')[-1]}." in pad or \
        modname.startswith(dotted_pkg)


def _check_r3(mods, out) -> None:
    for m in mods:
        if m.modname.startswith("repro.kernels") or \
                _in_scope(m.modname, "repro.kernels"):
            continue
        for node in ast.walk(m.tree):
            hits = []
            if isinstance(node, ast.ImportFrom) and node.module:
                if any(node.module.startswith(pkg + ".")
                       for pkg in KERNEL_INTERNALS):
                    hits.append(node.module)
                elif node.module in KERNEL_INTERNALS:
                    # package-surface names (the resolve_spec-routed
                    # dispatchers) are sanctioned; submodules are not
                    hits.extend(f"{node.module}.{a.name}"
                                for a in node.names
                                if a.name in KERNEL_SUBMODULES)
            elif isinstance(node, ast.Import):
                hits.extend(a.name for a in node.names
                            if any(a.name.startswith(pkg + ".")
                                   for pkg in KERNEL_INTERNALS))
            for h in hits:
                out.append(_finding(
                    "R3", m, node,
                    f"direct kernel-internal import {h} — call sites "
                    f"resolve through repro.kernels.backend"))


def _check_r4(mods, out) -> None:
    for m in mods:
        if m.modname == COMPAT_MODULE or m.path.endswith("repro/compat.py"):
            continue
        for node in ast.walk(m.tree):
            msg = None
            if isinstance(node, ast.ImportFrom) and node.module:
                if node.module.startswith("jax.experimental.shard_map"):
                    msg = f"raw import from {node.module}"
                elif node.module == "jax.experimental" and \
                        any(a.name == "shard_map" for a in node.names):
                    msg = "raw import of jax.experimental.shard_map"
                elif node.module == "jax.sharding" and \
                        any(a.name == "Mesh" for a in node.names):
                    msg = "raw import of jax.sharding.Mesh"
            elif isinstance(node, ast.Import):
                if any(a.name.startswith("jax.experimental.shard_map")
                       for a in node.names):
                    msg = "raw import of jax.experimental.shard_map"
            elif isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d in ("jax.make_mesh", "jax.sharding.Mesh",
                         "jax.experimental.shard_map.shard_map"):
                    msg = f"raw {d}() call"
            if msg:
                out.append(_finding(
                    "R4", m, node,
                    f"{msg} outside repro.compat — the shim is the one "
                    f"place tracking the moving JAX API"))


def _check_r5(mods, reachable, out) -> None:
    for m in mods:
        if not _in_scope(m.modname, "repro.core"):
            continue
        for fn in m.functions:
            if id(fn) not in reachable:
                continue
            for sub in ast.walk(fn.node):
                if isinstance(sub, (ast.If, ast.While, ast.IfExp)) \
                        and _has_array_call(sub.test):
                    out.append(_finding(
                        "R5", m, sub,
                        f"Python branch on a traced value in "
                        f"{fn.qualname} — use jnp.where / lax.cond"))


def _check_r6(mods, out) -> None:
    """State-update factories declare donation (R6).

    A *state-update factory* is any function that ``jax.jit``s a nested
    function whose first parameter is named ``state`` or ``leaves`` —
    the repo-wide naming convention for the operand the single-owner
    protocol donates (`STATE_OPERANDS`). Such a factory must carry
    ``donate_argnums`` on at least one of its jit calls (the
    ``... if cfg.donate else ...`` conditional counts: both branches
    are separate Call nodes and the donating one satisfies the rule).
    Read-only overlay factories suppress with a rationale."""
    for m in mods:
        if not any(_in_scope(m.modname, f"repro.{leaf}")
                   for leaf in R6_SCOPES):
            continue
        nested = {}
        for fn in m.functions:
            nested.setdefault(fn.qualname, fn)
        for fn in m.functions:
            jit_calls = [sub for sub in ast.walk(fn.node)
                         if isinstance(sub, ast.Call)
                         and _is_jit(sub.func)]
            if not jit_calls:
                continue
            stateful = False
            for call in jit_calls:
                for name in _jit_targets(call):
                    target = nested.get(f"{fn.qualname}.{name}")
                    if target is None:
                        continue
                    args = target.node.args.args
                    if args and args[0].arg in STATE_OPERANDS:
                        stateful = True
            if not stateful:
                continue
            if any(kw.arg == "donate_argnums"
                   for call in jit_calls for kw in call.keywords):
                continue
            flagged = min(jit_calls, key=lambda c: c.lineno)
            out.append(_finding(
                "R6", m, flagged,
                f"{fn.qualname} jits a state-update program without "
                f"donate_argnums — the update compiles to an A/B copy "
                f"instead of an in-place aliased write"))


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def _expand(paths) -> list[str]:
    files = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                files.extend(os.path.join(root, n)
                             for n in sorted(names) if n.endswith(".py"))
        else:
            files.append(p)
    return files


def lint_paths(paths, *, repo_root: str | None = None,
               baseline_keys=frozenset()) -> list[Finding]:
    """Run all rules over ``paths`` (files or directories).

    Returns EVERY finding; suppressed / baselined ones come back with
    the matching flag set (``Finding.active`` selects the gating set).
    """
    repo_root = repo_root or os.getcwd()
    mods = [collect_module(f, repo_root) for f in _expand(paths)]
    reachable = _reachable(mods)
    out: list[Finding] = []
    _check_r1(mods, reachable, out)
    _check_r2(mods, out)
    _check_r3(mods, out)
    _check_r4(mods, out)
    _check_r5(mods, reachable, out)
    _check_r6(mods, out)
    by_mod = {m.path: m for m in mods}
    for f in out:
        sup = by_mod[f.path].suppressions
        if f.rule in sup.get(f.line, ()):
            f.suppressed = True
        if f.key in baseline_keys:
            f.baselined = True
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out
