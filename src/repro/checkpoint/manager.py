"""Checkpointing: atomic, optionally async, latest-k retention, and
elastic restore (re-shard to the *current* mesh on load).

Layout: <dir>/step_<N>/  with one .npy per flattened leaf plus a
manifest.json carrying the keypaths and the data-pipeline cursor. Writes
go to step_<N>.tmp and are renamed atomically; a crash mid-write never
corrupts the latest valid checkpoint (fault-tolerance story, DESIGN.md §7).

Single-process layout; in a multi-host deployment each process writes its
addressable shards under process_<i>/ (same manifest format) — the
restore path re-shards whatever full arrays it finds via device_put with
the target sharding, which is exactly the elastic-restart path.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
    keyed = {}
    for path, leaf in leaves:
        key = "/".join(re.sub(r"[^A-Za-z0-9_.-]", "_", str(p))
                       for p in path)
        keyed[key] = leaf
    return keyed, treedef


def save(ckpt_dir: str, step: int, state, extra: dict | None = None):
    """Synchronous atomic save."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keyed, _ = _flatten(state)
    manifest = {"step": step, "keys": list(keyed), "extra": extra or {}}
    for key, leaf in keyed.items():
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, key.replace("/", "__") + ".npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_state, step: int | None = None,
            shardings=None):
    """Restore into the structure of target_state. With `shardings` (a
    matching pytree of NamedSharding), arrays are device_put with the
    *current* mesh layout — elastic restart onto a different topology."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    keyed, treedef = _flatten(target_state)
    arrays = []
    sh_keyed = None
    if shardings is not None:
        sh_keyed, _ = _flatten(shardings)
    for key, tgt in keyed.items():
        arr = np.load(os.path.join(d, key.replace("/", "__") + ".npy"))
        if hasattr(tgt, "dtype"):
            arr = arr.astype(tgt.dtype)
        if sh_keyed is not None:
            arrays.append(jax.device_put(arr, sh_keyed[key]))
        else:
            arrays.append(jax.numpy.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, arrays)
    return state, step, manifest.get("extra", {})


class CheckpointManager:
    """Async writer + latest-k retention."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_save: bool = True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.dir)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def save(self, step: int, state, extra: dict | None = None):
        # materialize on host *before* handing to the writer thread so the
        # training step can donate/overwrite device buffers safely
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self.wait()

        def work():
            save(self.dir, step, host_state, extra)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, target_state, step=None, shardings=None):
        self.wait()
        return restore(self.dir, target_state, step, shardings)
