"""Functional module substrate: declarative parameter plans, sharding
rules, and shared layers.

A *plan* is a pytree (nested dicts) of `PSpec` leaves describing every
parameter: shape, logical axes, initializer. From one plan we derive
  * materialized parameters        (`init_params` — smoke tests/training),
  * abstract parameters            (`abstract_params` — dry-run, zero
                                    allocation: ShapeDtypeStructs carrying
                                    NamedShardings),
  * PartitionSpecs                 (`plan_pspecs` — jit in_shardings).

Logical axes → mesh axes via RULES (MaxText-style), overridable per run —
this indirection is the main §Perf lever (change a rule, re-lower,
re-measure the roofline terms).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["PSpec", "init_params", "abstract_params", "plan_pspecs",
           "stack_plan", "Sharder", "DEFAULT_RULES", "rmsnorm", "RMSNORM_EPS",
           "dense", "Dtypes", "cross_entropy"]


@dataclasses.dataclass(frozen=True)
class PSpec:
    """Declarative parameter leaf."""
    shape: tuple
    axes: tuple            # logical axis name (or None) per dim
    init: str = "normal"   # normal | zeros | ones | scaled(fan-in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# Default logical→mesh axis rules (production mesh axes: pod/data/model).
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,           # activations' sequence dim (train)
    "attn_seq": None,      # attention q-seq (SP fallback when heads can't
                           # shard over the model axis)
    "kv_seq": None,        # KV-cache sequence dim (set to "data" for SP)
    "embed": None,         # weights' model dim; "data" = FSDP/ZeRO-3
    "act_embed": None,     # activations' model dim (kept separate from the
                           # weight axis so FSDP never shards activations)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "expert_embed": None,  # TP-regime MoE: "data" = FSDP on expert weights
    "layer": None,
    "state": None,
    "conv": None,
}


def _is_leaf(x):
    return isinstance(x, PSpec)


def _axes_to_pspec(axes, rules) -> P:
    return P(*(rules.get(a) if a is not None else None for a in axes))


def init_params(plan, key: jax.Array, dtype=None):
    """Materialize parameters (deterministic per-path keys)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        plan, is_leaf=_is_leaf)

    arrays = []
    for path, spec in leaves:
        pathstr = "/".join(str(k) for k in path)
        k = jax.random.fold_in(key, hash(pathstr) % (2 ** 31))
        dt = dtype or spec.dtype
        if spec.init == "zeros":
            a = jnp.zeros(spec.shape, dt)
        elif spec.init == "ones":
            a = jnp.ones(spec.shape, dt)
        elif spec.init == "normal":
            a = (jax.random.normal(k, spec.shape, jnp.float32)
                 * 0.02).astype(dt)
        elif spec.init == "scaled":  # fan-in scaling on the 2nd-to-last dim
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            a = (jax.random.normal(k, spec.shape, jnp.float32)
                 * (fan_in ** -0.5)).astype(dt)
        else:
            raise ValueError(spec.init)
        arrays.append(a)
    return jax.tree_util.tree_unflatten(treedef, arrays)


def abstract_params(plan, mesh=None, rules=None, dtype=None):
    """ShapeDtypeStructs (with shardings if mesh given) — dry-run stand-ins."""
    rules = rules or DEFAULT_RULES

    def leaf(spec: PSpec):
        dt = dtype or spec.dtype
        if mesh is None:
            return jax.ShapeDtypeStruct(spec.shape, dt)
        sh = NamedSharding(mesh, _axes_to_pspec(spec.axes, rules))
        return jax.ShapeDtypeStruct(spec.shape, dt, sharding=sh)

    return jax.tree.map(leaf, plan, is_leaf=_is_leaf)


def plan_pspecs(plan, rules=None):
    rules = rules or DEFAULT_RULES
    return jax.tree.map(lambda s: _axes_to_pspec(s.axes, rules), plan,
                        is_leaf=_is_leaf)


def stack_plan(plan, n: int, axis_name: str = "layer"):
    """Prefix every leaf with a stacked layer dimension (scan-over-layers)."""
    return jax.tree.map(
        lambda s: PSpec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                        s.dtype),
        plan, is_leaf=_is_leaf)


class Sharder:
    """Activation-sharding helper: maps logical axes through the rules and
    applies with_sharding_constraint (no-op when disabled — CPU smoke)."""

    def __init__(self, rules=None, enabled: bool = True):
        self.rules = rules or DEFAULT_RULES
        self.enabled = enabled

    def __call__(self, x, *axes):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(
            x, _axes_to_pspec(axes, self.rules))


@dataclasses.dataclass(frozen=True)
class Dtypes:
    param: Any = jnp.float32
    compute: Any = jnp.bfloat16
    norm: Any = jnp.float32  # norms & softmax/loss stay f32


RMSNORM_EPS = 1e-6


def rmsnorm(x, scale, eps: float = RMSNORM_EPS):
    """RMSNorm in f32 regardless of input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def dense(x, w, compute_dtype=jnp.bfloat16):
    """x @ w in the compute dtype."""
    return jnp.einsum("...d,df->...f", x.astype(compute_dtype),
                      w.astype(compute_dtype))


def cross_entropy(logits, labels, mask=None):
    """Token-level CE in f32; labels (B, S) int32, logits (B, S, V).

    The label log-prob is contracted via a one-hot product rather than
    take_along_axis: a gather along the vocab dim would make GSPMD
    all-gather the (vocab-sharded) logits; the elementwise product +
    reduction partitions cleanly (psum of per-shard partials)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.sum(logits * onehot, axis=-1)
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
