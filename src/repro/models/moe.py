"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is sort-free: positions inside each expert's buffer come from a
cumsum over the one-hot assignment matrix (T, E) — cheap, static-shape, and
SPMD-partitionable over the token axis. Tokens beyond an expert's capacity
are dropped (standard GShard/Switch semantics); the combine gather fills
dropped slots with zeros so the residual path carries them through.

Sharding: expert weights are (E, D, F). Two regimes, chosen per arch by the
rules (DESIGN.md §6):
  * EP  — "expert" -> model axis (E divisible by axis, e.g. llama4 128/16);
  * TP  — "expert_mlp" -> model axis (few big experts, e.g. mixtral 8x7b).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.common import PSpec

__all__ = ["moe_plan", "moe_apply"]


def moe_plan(d_model: int, d_ff: int, n_experts: int,
             shared_expert: bool = False):
    plan = {
        "router": PSpec((d_model, n_experts), ("embed", "expert"), "scaled"),
        "wi": PSpec((n_experts, d_model, d_ff),
                    ("expert", "expert_embed", "expert_mlp"), "scaled"),
        "wg": PSpec((n_experts, d_model, d_ff),
                    ("expert", "expert_embed", "expert_mlp"), "scaled"),
        "wo": PSpec((n_experts, d_ff, d_model),
                    ("expert", "expert_mlp", "expert_embed"), "scaled"),
    }
    if shared_expert:
        plan["shared"] = {
            "wi": PSpec((d_model, d_ff), ("embed", "mlp"), "scaled"),
            "wg": PSpec((d_model, d_ff), ("embed", "mlp"), "scaled"),
            "wo": PSpec((d_ff, d_model), ("mlp", "embed"), "scaled"),
        }
    return plan


def moe_apply(params, x, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, compute_dtype=jnp.bfloat16,
              sharder=None):
    """x: (B, S, D) -> (B, S, D), aux metrics dict."""
    b, s, d = x.shape
    t = b * s
    e = n_experts
    dt = compute_dtype
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)                  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- dispatch: cumsum positions, capacity drop ---
    # Distributed: scatter/gather with data-dependent indices across the
    # sharded token dim makes GSPMD replicate the dispatch buffers
    # (measured 60+ GiB/chip on mixtral), so dispatch/combine run *locally
    # per data shard* under shard_map with per-shard capacity — the
    # standard per-device-capacity MoE formulation. The expert einsums in
    # between stay in jit-land so the weight shardings (EP/TP) apply.
    distributed = sharder is not None and sharder.enabled
    tok_axes = sharder.rules.get("batch") if distributed else None
    tok_spec = P(tok_axes) if distributed else None
    choice = idx.reshape(t * top_k)                          # (Tk,)

    def dispatch(xt_l, choice_l):
        t_l = xt_l.shape[0]
        cap_l = max(1, int(capacity_factor * t_l * top_k / e))
        onehot = jax.nn.one_hot(choice_l, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        mypos = jnp.take_along_axis(pos, choice_l[:, None], axis=1)[:, 0]
        ok_l = mypos < cap_l
        dest_l = jnp.where(ok_l, choice_l * cap_l + mypos, e * cap_l)
        xrep = jnp.repeat(xt_l, top_k, axis=0)               # (T_l k, D)
        buf_l = jnp.zeros((e * cap_l, d), dt).at[dest_l].set(
            xrep.astype(dt), mode="drop").reshape(e, cap_l, d)
        return buf_l, dest_l, ok_l

    def combine(y_l, dest_l, gate_l):
        cap_l = y_l.shape[1]
        yfl = y_l.reshape(e * cap_l, d)
        ytok = jnp.take(yfl, dest_l, axis=0, mode="fill", fill_value=0)
        t_l = dest_l.shape[0] // top_k
        return (ytok.reshape(t_l, top_k, d)
                * gate_l[..., None].astype(dt)).sum(axis=1)

    if distributed:
        buf, dest, ok = shard_map(
            dispatch,
            in_specs=(P(tok_axes, None), P(tok_axes)),
            out_specs=(P(None, tok_axes, None), P(tok_axes),
                       P(tok_axes)),
            check_vma=False)(xt, choice)
    else:
        buf, dest, ok = dispatch(xt, choice)

    # --- expert FFN (SwiGLU); weights sharded per the rules (EP/TP) ---
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g,
                   params["wo"].astype(dt))

    if distributed:
        y = jax.lax.with_sharding_constraint(y, P(None, tok_axes, None))
        out = shard_map(
            combine,
            in_specs=(P(None, tok_axes, None), P(tok_axes), P(tok_axes)),
            out_specs=P(tok_axes, None),
            check_vma=False)(y, dest, gate)
    else:
        out = combine(y, dest, gate)

    if "shared" in params:
        sh = params["shared"]
        hh = jnp.einsum("td,df->tf", xt.astype(dt), sh["wi"].astype(dt))
        gg = jnp.einsum("td,df->tf", xt.astype(dt), sh["wg"].astype(dt))
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(hh) * gg,
                               sh["wo"].astype(dt))

    # Switch-style load-balance aux loss terms
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    pmean = jnp.mean(probs, axis=0)
    aux = {"moe_aux_loss": e * jnp.sum(frac * pmean),
           "moe_drop_frac": 1.0 - jnp.mean(ok.astype(jnp.float32))}
    return out.reshape(b, s, d), aux
