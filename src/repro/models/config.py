"""Model configuration dataclass shared by the model zoo and configs/."""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention flavour
    qk_norm: bool = False
    rope_theta: float = 1e4
    attn_kind: str = "causal"   # causal | window | chunk | bidir | prefix
    window: int = 0             # sliding-window size (attn_kind="window")
    chunk: int = 0              # local-chunk size (attn_kind="chunk")
    global_every: int = 0       # llama4 iRoPE: every k-th layer global NoPE
    mlp_kind: str = "swiglu"    # swiglu | gelu

    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE every k-th layer (llama4: 2)
    shared_expert: bool = False
    capacity_factor: float = 1.25

    # ssm / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0          # d_inner = ssm_heads * ssm_head_dim
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0         # hybrid: shared attn before layers i%k==0

    # modality frontend stubs (audio/vlm)
    frontend_dim: int = 0       # >0: inputs are precomputed embeddings
    prefix_len: int = 0         # vlm: number of image-prefix tokens

    # execution
    tie_embeddings: bool = True
    remat: bool = True
    scan_layers: bool = True
    scan_unroll: bool = False   # dry-run cost probes: fully unroll scans
    blockwise_threshold: int = 8192
    attn_block_k: int = 1024
    param_dtype: str = "float32"     # llama4: bfloat16 (DESIGN.md §6)
    compute_dtype: str = "bfloat16"
    microbatches: int = 1            # grad-accumulation steps per train step

    # ---- derived ----
    @property
    def head_dim_eff(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_heads * self.ssm_head_dim

    @property
    def n_attn_apps(self) -> int:
        """Hybrid: number of shared-attention applications."""
        if not self.attn_every:
            return 0
        return -(-self.n_layers // self.attn_every)

    def sub_pattern(self):
        """llama4 super-layer: per-sub (attn_is_global, ffn_is_moe)."""
        period = self.global_every or 1
        return [((i + 1) % (self.global_every or 10 ** 9) == 0,
                 self.n_experts > 0 and (i + 1) % self.moe_every == 0)
                for i in range(period)]

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        hq, hk, dh = self.n_heads, self.n_kv_heads, self.head_dim_eff
        attn = d * dh * (hq + 2 * hk) + hq * dh * d
        mlp = d * f * (3 if self.mlp_kind == "swiglu" else 2)
        moe = 0
        if self.n_experts:
            moe = self.n_experts * 3 * d * f + d * self.n_experts
            if self.shared_expert:
                moe += 3 * d * f
        ssm = 0
        if self.ssm_heads:
            h, p, n = self.ssm_heads, self.ssm_head_dim, self.ssm_state
            ssm = d * h * p * 2 + 2 * d * n + d * h + h * p * d
        total = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "encoder", "vlm"):
            total += self.n_layers * (attn + mlp)
        elif self.family == "moe":
            n_moe = self.n_layers // self.moe_every
            total += self.n_layers * attn + n_moe * moe \
                + (self.n_layers - n_moe) * mlp
        elif self.family == "ssm":
            total += self.n_layers * ssm
        elif self.family == "hybrid":
            total += self.n_layers * ssm + (attn + mlp)  # one shared block
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k only)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = self.param_count()
        n_moe = self.n_layers // self.moe_every
        all_experts = n_moe * self.n_experts * 3 * d * f
        active = n_moe * self.top_k * 3 * d * f
        return dense_like - all_experts + active
