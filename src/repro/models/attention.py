"""Attention: GQA/MQA/MHA with RoPE/NoPE, qk-norm, full / sliding-window /
chunked-local / prefix-LM masking, flash-style blockwise execution for long
sequences, and static-shape KV caches (full and rolling) for decode.

Layout convention: q is grouped as (B, Hkv, G, Sq, Dh) with G = Hq // Hkv;
k/v are (B, Hkv, Sk, Dh). Softmax accumulates in f32.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import PSpec, rmsnorm

__all__ = ["attn_plan", "init_rope", "apply_rope", "attention_train",
           "init_cache", "attention_decode", "KVCache", "MASK_KINDS"]

MASK_KINDS = ("causal", "window", "chunk", "bidir", "prefix")
_NEG = -1e30


# --------------------------------------------------------------------------
# Parameter plan
# --------------------------------------------------------------------------

def attn_plan(d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool = False):
    plan = {
        "wq": PSpec((d_model, n_heads, head_dim),
                    ("embed", "heads", "head_dim"), "scaled"),
        "wk": PSpec((d_model, n_kv, head_dim),
                    ("embed", "kv_heads", "head_dim"), "scaled"),
        "wv": PSpec((d_model, n_kv, head_dim),
                    ("embed", "kv_heads", "head_dim"), "scaled"),
        "wo": PSpec((n_heads, head_dim, d_model),
                    ("heads", "head_dim", "embed"), "scaled"),
    }
    if qk_norm:
        plan["q_norm"] = PSpec((head_dim,), ("head_dim",), "zeros")
        plan["k_norm"] = PSpec((head_dim,), ("head_dim",), "zeros")
    return plan


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def init_rope(head_dim: int, theta: float = 1e4):
    return _rope_freqs(head_dim, theta)


def apply_rope(x, positions, freqs):
    """x: (..., S, Dh); positions: (S,) or broadcastable."""
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (S, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Masks — defined pointwise over (q_pos, k_pos) so blockwise attention can
# evaluate them per tile without materializing (S, S).
# --------------------------------------------------------------------------

def mask_block(kind: str, q_pos, k_pos, *, window: int = 0, chunk: int = 0,
               prefix_len=None):
    """(Sq, Bk) bool tile of the attention mask."""
    q = q_pos[:, None]
    k = k_pos[None, :]
    causal = k <= q
    if kind == "causal":
        return causal
    if kind == "window":
        return causal & (q - k < window)
    if kind == "chunk":
        return causal & (q // chunk == k // chunk)
    if kind == "bidir":
        return jnp.ones_like(causal)
    if kind == "prefix":
        return causal | (k < prefix_len)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Projections
# --------------------------------------------------------------------------

def _project_qkv(params, x, cfg_dt, n_heads, n_kv, qk_norm):
    dt = cfg_dt
    q = jnp.einsum("bsd,dhk->bshk", x.astype(dt), params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x.astype(dt), params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x.astype(dt), params["wv"].astype(dt))
    if qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    return q, k, v


def _repeat_kv(k, n_heads):
    """(B, Hkv, S, Dh) -> (B, Hq, S, Dh): repeat KV across query groups.
    Standard GQA tensor-parallel layout — the head dim of every attention
    intermediate is the full Hq, shardable over the model axis even when
    Hkv is smaller than it (DESIGN.md §6)."""
    b, hkv, s, dh = k.shape
    g = n_heads // hkv
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=1)


# --------------------------------------------------------------------------
# Training / prefill attention
# --------------------------------------------------------------------------

def _attn_full(q, k, v, mask):
    """q/k/v: (B, Hq, S, Dh); mask: (Sq, Sk)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    logits = logits * scale + jnp.where(mask, 0.0, _NEG)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v)


def _attn_blockwise(q, k, v, kind, *, window, chunk, prefix_len, block_k,
                    unroll=False):
    """Flash-style online-softmax scan over key blocks (no (S,S) buffer)."""
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    assert sk % block_k == 0, (sk, block_k)
    nb = sk // block_k
    scale = dh ** -0.5
    q_pos = jnp.arange(sq)

    kb = k.reshape(b, h, nb, block_k, dh).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, h, nb, block_k, dh).transpose(2, 0, 1, 3, 4)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, i = blk
        k_pos = i * block_k + jnp.arange(block_k)
        msk = mask_block(kind, q_pos, k_pos, window=window, chunk=chunk,
                         prefix_len=prefix_len)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, kblk)
        logits = logits.astype(jnp.float32) * scale + jnp.where(
            msk, 0.0, _NEG)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vblk.dtype), vblk
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kb, vb, jnp.arange(nb)),
        unroll=True if unroll else 1)
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def attention_train(params, x, *, n_heads, n_kv, head_dim, compute_dtype,
                    rope_freqs=None, kind="causal", window=0, chunk=0,
                    prefix_len=None, qk_norm=False, block_k: int = 1024,
                    blockwise_threshold: int = 8192, sharder=None,
                    unroll=False):
    """Self-attention over a full sequence (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, compute_dtype, n_heads, n_kv, qk_norm)
    pos = jnp.arange(s)
    if rope_freqs is not None:
        q = apply_rope(q.transpose(0, 2, 1, 3), pos, rope_freqs
                       ).transpose(0, 2, 1, 3)
        k = apply_rope(k.transpose(0, 2, 1, 3), pos, rope_freqs
                       ).transpose(0, 2, 1, 3)
    qg = q.transpose(0, 2, 1, 3)               # (B,Hq,S,Dh)
    kh = k.transpose(0, 2, 1, 3)               # (B,Hkv,S,Dh)
    vh = v.transpose(0, 2, 1, 3)
    if sharder is not None:
        # heads shard over the model axis when divisible; otherwise the
        # rules set attn_seq -> model (sequence-parallel attention), so the
        # quadratic (Sq, Sk) intermediates always shard over the mesh.
        qg = sharder(qg, "batch", "heads", "attn_seq", None)
        # K/V are materialized across the model axis *before* the GQA
        # repeat: gathering the repeated (Hq) tensor would move
        # Hq/Hkv x more bytes (§Perf hillclimb 1: 5x on qwen3-14b).
        kh = sharder(kh, "batch", "kv_heads", "kv_seq", None)
        vh = sharder(vh, "batch", "kv_heads", "kv_seq", None)

    kf = _repeat_kv(kh, n_heads)               # (B,Hq,S,Dh) — local
    vf = _repeat_kv(vh, n_heads)
    if s <= blockwise_threshold:
        msk = mask_block(kind, pos, pos, window=window, chunk=chunk,
                         prefix_len=prefix_len)
        out = _attn_full(qg, kf, vf, msk)
    else:
        out = _attn_blockwise(qg, kf, vf, kind, window=window, chunk=chunk,
                              prefix_len=prefix_len, block_k=block_k,
                              unroll=unroll)

    out = out.transpose(0, 2, 1, 3)            # (B,S,Hq,Dh)
    if sharder is not None:
        out = sharder(out, "batch", "attn_seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(compute_dtype),
                   params["wo"].astype(compute_dtype))
    return (kh, vh), y


# --------------------------------------------------------------------------
# Decode: static-shape KV caches
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class KVCache:
    """Static-shape KV cache; `rolling` is static pytree aux-data."""

    def __init__(self, k, v, kpos, rolling: bool):
        self.k = k           # (B, W, Hkv, Dh)
        self.v = v           # (B, W, Hkv, Dh)
        self.kpos = kpos     # (W,) int32 absolute positions, -1 = empty
        self.rolling = rolling

    def _replace(self, **kw):
        d = dict(k=self.k, v=self.v, kpos=self.kpos, rolling=self.rolling)
        d.update(kw)
        return KVCache(**d)

    def tree_flatten(self):
        return (self.k, self.v, self.kpos), self.rolling

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, rolling=aux)


def init_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
               dtype, rolling: bool) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        kpos=jnp.full((capacity,), -1, jnp.int32),
        rolling=rolling)


def cache_from_prefill(k, v, capacity: int, rolling: bool) -> KVCache:
    """k/v: (B, Hkv, S, Dh) from attention_train — keep the last `capacity`
    positions (exact for rolling windows >= window size).

    Scatter-free: a scatter along the (sequence-sharded) cache dim makes
    GSPMD replicate the whole cache ("involuntary full rematerialization"),
    so the layouts are built from pads/rolls only."""
    b, h, s, dh = k.shape
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    take = min(s, capacity)
    start = s - take
    if not rolling:
        # capacity >= s: right-pad to capacity
        pad = capacity - take
        kc = jnp.pad(kk[:, start:], ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(vv[:, start:], ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.concatenate([jnp.arange(start, s, dtype=jnp.int32),
                                jnp.full((pad,), -1, jnp.int32)])
        return KVCache(kc, vc, kpos, rolling=False)
    # rolling ring buffer: the last `capacity` positions, rotated so that
    # absolute position p lives in slot p % capacity
    kt = kk[:, -take:]
    vt = vv[:, -take:]
    if take < capacity:
        kt = jnp.pad(kt, ((0, 0), (0, capacity - take), (0, 0), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, capacity - take), (0, 0), (0, 0)))
    kpos_lin = jnp.concatenate([
        jnp.arange(start, s, dtype=jnp.int32),
        jnp.full((capacity - take,), -1, jnp.int32)])
    shift = start % capacity
    return KVCache(jnp.roll(kt, shift, axis=1), jnp.roll(vt, shift, axis=1),
                   jnp.roll(kpos_lin, shift), rolling=True)


def attention_decode(params, x, cache: KVCache, pos, *, n_heads, n_kv,
                     head_dim, compute_dtype, rope_freqs=None,
                     kind="causal", window=0, chunk=0, qk_norm=False,
                     sharder=None):
    """One-token decode step. x: (B, 1, D); pos: () int32 absolute position.
    Returns (new_cache, y)."""
    b = x.shape[0]
    q, k, v = _project_qkv(params, x, compute_dtype, n_heads, n_kv, qk_norm)
    if rope_freqs is not None:
        pvec = jnp.full((1,), pos)
        q = apply_rope(q.transpose(0, 2, 1, 3), pvec, rope_freqs
                       ).transpose(0, 2, 1, 3)
        k = apply_rope(k.transpose(0, 2, 1, 3), pvec, rope_freqs
                       ).transpose(0, 2, 1, 3)

    w = cache.k.shape[1]
    slot = pos % w if cache.rolling else jnp.minimum(pos, w - 1)
    # mask-select write: a dynamic_update_slice along the (sequence-
    # sharded) cache dim would force GSPMD to replicate the whole cache;
    # the elementwise select partitions cleanly across shards.
    sel = (jnp.arange(w) == slot)
    new = cache._replace(
        k=jnp.where(sel[None, :, None, None], k.astype(cache.k.dtype),
                    cache.k),
        v=jnp.where(sel[None, :, None, None], v.astype(cache.v.dtype),
                    cache.v),
        kpos=jnp.where(sel, pos.astype(jnp.int32), cache.kpos))
    if sharder is not None:
        new = new._replace(k=sharder(new.k, "batch", "kv_seq", "kv_heads",
                                     None),
                           v=sharder(new.v, "batch", "kv_seq", "kv_heads",
                                     None))

    # grouped-query attention directly against the unrepeated cache:
    # repeating KV to Hq heads would materialize (and read) the cache
    # Hq/Hkv x per step — decode is cache-bandwidth-bound, so the repeat
    # dominated HLO bytes (§Perf hillclimb 2).
    b_, _, hq, dh_ = q.shape
    g = hq // n_kv
    qg = q.reshape(b_, n_kv, g, dh_)                    # (B,Hkv,G,Dh)
    kh = new.k.transpose(0, 2, 1, 3)                    # (B,Hkv,W,Dh)
    vh = new.v.transpose(0, 2, 1, 3)
    kpos = new.kpos
    valid = kpos >= 0
    if kind == "window" and window:
        valid &= (pos - kpos) < window
    if kind == "chunk" and chunk:
        valid &= (kpos // chunk) == (pos // chunk)
    valid &= kpos <= pos

    scale = head_dim ** -0.5
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, kh).astype(jnp.float32)
    logits = logits * scale + jnp.where(valid[None, None, None, :],
                                        0.0, _NEG)
    wts = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", wts.astype(vh.dtype), vh)
    out = out.reshape(b_, 1, hq, dh_)                   # (B,1,Hq,Dh)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(compute_dtype),
                   params["wo"].astype(compute_dtype))
    return new, y
