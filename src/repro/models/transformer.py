"""Model zoo wiring: decoder LMs (dense / MoE / iRoPE-MoE), pure-SSM,
hybrid SSM+shared-attention, encoder-only, and VLM (prefix-LM), all built
from the layer library with scan-over-layers stacking (compile-time
friendly at 32-48 layers) and optional remat.

Public surface:
  lm_plan(cfg)                              parameter plan
  forward(params, cfg, inputs, ...)         logits (train/encoder fwd)
  loss_fn(params, cfg, batch, ...)          scalar loss + metrics
  prefill(params, cfg, inputs, cache_len)   caches + last-position logits
  decode_step(params, cfg, caches, token, pos)  one-token decode
  init_caches(cfg, batch, cache_len, ...)   decode-state pytree (+factory
                                            for abstract dry-run specs)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (PSpec, Sharder, cross_entropy, rmsnorm,
                                 stack_plan)
from repro.models.config import ModelConfig

__all__ = ["lm_plan", "forward", "loss_fn", "prefill", "decode_step",
           "init_caches", "cache_axes"]


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


# ==========================================================================
# Parameter plans
# ==========================================================================

def _mlp_plan(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    plan = {"wi": PSpec((d, f), ("embed", "mlp"), "scaled"),
            "wo": PSpec((f, d), ("mlp", "embed"), "scaled")}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        plan["wg"] = PSpec((d, f), ("embed", "mlp"), "scaled")
    return plan


def _attn_block_plan(cfg: ModelConfig, moe: bool):
    plan = {
        "ln1": PSpec((cfg.d_model,), ("embed",), "zeros"),
        "attn": att.attn_plan(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim_eff, cfg.qk_norm),
        "ln2": PSpec((cfg.d_model,), ("embed",), "zeros"),
    }
    if moe:
        plan["moe"] = moe_mod.moe_plan(cfg.d_model, cfg.d_ff, cfg.n_experts,
                                       cfg.shared_expert)
    else:
        plan["mlp"] = _mlp_plan(cfg)
    return plan


def _ssm_block_plan(cfg: ModelConfig):
    return {"ln": PSpec((cfg.d_model,), ("embed",), "zeros"),
            "mamba": ssm_mod.mamba2_plan(cfg.d_model, cfg.ssm_heads,
                                         cfg.ssm_head_dim, cfg.ssm_state)}


def lm_plan(cfg: ModelConfig):
    v, d = cfg.vocab_padded, cfg.d_model
    plan: dict[str, Any] = {
        "embed": PSpec((v, d), ("vocab", "embed"), "normal"),
        "final_norm": PSpec((d,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        plan["lm_head"] = PSpec((d, v), ("embed", "vocab"), "normal")
    if cfg.frontend_dim:
        plan["frontend_proj"] = PSpec((cfg.frontend_dim, d),
                                      (None, "embed"), "scaled")

    fam = cfg.family
    if fam in ("dense", "encoder", "vlm"):
        plan["blocks"] = stack_plan(_attn_block_plan(cfg, False),
                                    cfg.n_layers)
    elif fam == "moe" and cfg.global_every:
        # iRoPE super-layers: one stacked plan per sub-position
        period = cfg.global_every
        assert cfg.n_layers % period == 0, (cfg.n_layers, period)
        subs = {}
        for i, (_, is_moe) in enumerate(cfg.sub_pattern()):
            subs[f"sub{i}"] = stack_plan(_attn_block_plan(cfg, is_moe),
                                         cfg.n_layers // period)
        plan["blocks"] = subs
    elif fam == "moe":
        assert cfg.moe_every == 1
        plan["blocks"] = stack_plan(_attn_block_plan(cfg, True),
                                    cfg.n_layers)
    elif fam == "ssm":
        plan["blocks"] = stack_plan(_ssm_block_plan(cfg), cfg.n_layers)
    elif fam == "hybrid":
        plan["blocks"] = stack_plan(_ssm_block_plan(cfg), cfg.n_layers)
        plan["shared_attn"] = _attn_block_plan(cfg, False)
    else:
        raise ValueError(fam)
    return plan


# ==========================================================================
# Block applications (full-sequence)
# ==========================================================================

def _mlp_apply(params, x, cfg, dt, sharder=None):
    del sharder  # explicit SP boundaries regressed (§Perf hillclimb 1c:
    # GSPMD's own weight-gather placement beats forced activation
    # replication — refuted hypothesis, kept for the record)
    h = jnp.einsum("bsd,df->bsf", x.astype(dt), params["wi"].astype(dt))
    if cfg.mlp_kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x.astype(dt),
                       params["wg"].astype(dt))
        h = jax.nn.silu(h) * g
    elif cfg.mlp_kind == "geglu":
        g = jnp.einsum("bsd,df->bsf", x.astype(dt),
                       params["wg"].astype(dt))
        h = jax.nn.gelu(h) * g
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dt))


def _attn_block(params, x, cfg, sharder, *, kind, use_rope, rope_freqs,
                prefix_len=None, is_moe=False):
    """Full-seq attention block -> (x, (k, v), aux)."""
    dt = _dt(cfg)
    h = rmsnorm(x, params["ln1"])
    kv, a = att.attention_train(
        params["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.head_dim_eff, compute_dtype=dt,
        rope_freqs=rope_freqs if use_rope else None, kind=kind,
        window=cfg.window, chunk=cfg.chunk, prefix_len=prefix_len,
        qk_norm=cfg.qk_norm, block_k=cfg.attn_block_k,
        blockwise_threshold=cfg.blockwise_threshold, sharder=sharder,
        unroll=cfg.scan_unroll)
    x = x + a.astype(x.dtype)
    h = rmsnorm(x, params["ln2"])
    aux = {}
    if is_moe:
        f, aux = moe_mod.moe_apply(
            params["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, compute_dtype=dt,
            sharder=sharder)
    else:
        f = _mlp_apply(params["mlp"], h, cfg, dt, sharder)
    x = x + f.astype(x.dtype)
    x = sharder(x, "batch", "seq", "act_embed")
    return x, kv, aux


def _ssm_block(params, x, cfg, sharder):
    dt = _dt(cfg)
    h = rmsnorm(x, params["ln"])
    y, state = ssm_mod.mamba2_apply(
        params["mamba"], h, n_heads=cfg.ssm_heads,
        head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
        chunk=cfg.ssm_chunk, compute_dtype=dt, sharder=sharder,
        unroll=cfg.scan_unroll)
    x = x + y.astype(x.dtype)
    x = sharder(x, "batch", "seq", "act_embed")
    return x, state


# ==========================================================================
# Embedding / head
# ==========================================================================

def _embed_lookup(embed, tokens, dt, sharder):
    """Plain row gather. (A one-hot contraction over the vocab-sharded
    table was tried to avoid f32 table gathers — collective-neutral but
    +6 GiB/chip of one-hot temporaries; refuted, §Perf hillclimb 1d.)"""
    del sharder
    return embed.astype(dt)[tokens]


def _embed_inputs(params, cfg, inputs, sharder):
    dt = _dt(cfg)
    if cfg.family == "encoder":
        x = jnp.einsum("bsf,fd->bsd", inputs["frames"].astype(dt),
                       params["frontend_proj"].astype(dt))
    elif cfg.family == "vlm":
        img = jnp.einsum("bsf,fd->bsd", inputs["image_emb"].astype(dt),
                         params["frontend_proj"].astype(dt))
        txt = _embed_lookup(params["embed"], inputs["tokens"], dt, sharder)
        x = jnp.concatenate([img, txt], axis=1)
    else:
        x = _embed_lookup(params["embed"], inputs["tokens"], dt, sharder)
    return sharder(x, "batch", "seq", "act_embed")


def _head(params, cfg, x, last_only: bool = False):
    dt = _dt(cfg)
    if last_only:
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"])
    if cfg.tie_embeddings:
        # contract directly against (V, D) — no transposed copy of the
        # (large, vocab-sharded) embedding is ever materialized
        return jnp.einsum("bsd,vd->bsv", x.astype(dt),
                          params["embed"].astype(dt)).astype(jnp.float32)
    return jnp.einsum("bsd,dv->bsv", x.astype(dt),
                      params["lm_head"].astype(dt)).astype(jnp.float32)


# ==========================================================================
# Forward (train / encoder / prefill collection)
# ==========================================================================

def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan(fn, init, xs, cfg):
    return jax.lax.scan(fn, init, xs, unroll=True if cfg.scan_unroll
                        else 1)


def forward(params, cfg: ModelConfig, inputs, *, sharder=None,
            collect_kv: bool = False, last_only: bool = False):
    """Returns (logits, collected, aux). collected is family-specific:
    stacked (k, v) or SSM states when collect_kv (prefill), else None."""
    sharder = sharder or Sharder(enabled=False)
    dt = _dt(cfg)
    x = _embed_inputs(params, cfg, inputs, sharder)
    rope_freqs = att.init_rope(cfg.head_dim_eff, cfg.rope_theta)
    prefix_len = cfg.prefix_len if cfg.family == "vlm" else None
    kind = ("bidir" if cfg.family == "encoder"
            else "prefix" if cfg.family == "vlm" else cfg.attn_kind)
    fam = cfg.family
    collected = None
    aux_sum = {}

    if fam in ("dense", "encoder", "vlm") or (fam == "moe"
                                              and not cfg.global_every):
        is_moe = fam == "moe"

        def blk(x, p):
            x, kv, aux = _attn_block(
                p, x, cfg, sharder, kind=kind, use_rope=True,
                rope_freqs=rope_freqs, prefix_len=prefix_len,
                is_moe=is_moe)
            ys = (kv if collect_kv else None,
                  aux.get("moe_aux_loss", jnp.float32(0.0)))
            return x, ys

        x, (kvs, auxs) = _scan(_maybe_remat(blk, cfg), x,
                               params["blocks"], cfg)
        collected = kvs
        aux_sum["moe_aux_loss"] = jnp.sum(auxs)

    elif fam == "moe":  # iRoPE super-layers (llama4)
        pattern = cfg.sub_pattern()

        def blk(x, p):
            kvs = []
            auxs = jnp.float32(0.0)
            for i, (is_global, is_moe) in enumerate(pattern):
                x, kv, aux = _attn_block(
                    p[f"sub{i}"], x, cfg, sharder,
                    kind="causal" if is_global else "chunk",
                    use_rope=not is_global, rope_freqs=rope_freqs,
                    is_moe=is_moe)
                kvs.append(kv)
                auxs = auxs + aux.get("moe_aux_loss", jnp.float32(0.0))
            return x, ((kvs if collect_kv else None), auxs)

        x, (kvs, auxs) = _scan(_maybe_remat(blk, cfg), x,
                               params["blocks"], cfg)
        collected = kvs
        aux_sum["moe_aux_loss"] = jnp.sum(auxs)

    elif fam == "ssm":
        def blk(x, p):
            x, st = _ssm_block(p, x, cfg, sharder)
            return x, (st if collect_kv else None)

        x, states = _scan(_maybe_remat(blk, cfg), x, params["blocks"],
                          cfg)
        collected = states

    elif fam == "hybrid":
        # static group structure: shared attention once per `attn_every`
        # mamba layers (a lax.cond-in-scan alternative compiled BOTH
        # branches at every layer — 5.4x attention flop overcount and
        # dynamic cache updates; §Perf hillclimb 3)
        period = cfg.attn_every
        shared = params["shared_attn"]
        states_chunks, kvs = [], []

        def blk(x, p):
            x, st = _ssm_block(p, x, cfg, sharder)
            return x, (st if collect_kv else None)

        def attn_once(x):
            x, kv, _ = _attn_block(shared, x, cfg, sharder, kind="causal",
                                   use_rope=True, rope_freqs=rope_freqs)
            return x, kv

        for app in range(cfg.n_attn_apps):
            x, kv = _maybe_remat(attn_once, cfg)(x)
            kvs.append(kv)
            lo = app * period
            hi = min(lo + period, cfg.n_layers)
            blk_params = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
            x, sts = _scan(_maybe_remat(blk, cfg), x, blk_params, cfg)
            states_chunks.append(sts)
        if collect_kv:
            states = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, 0), *states_chunks)
            kv_st = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *kvs)
            collected = (states, kv_st)
        else:
            collected = None
    else:
        raise ValueError(fam)

    logits = _head(params, cfg, x, last_only=last_only)
    return logits, collected, aux_sum


def loss_fn(params, cfg: ModelConfig, batch, *, sharder=None):
    logits, _, aux = forward(params, cfg, batch, sharder=sharder)
    labels = batch["labels"]
    if cfg.family == "vlm":  # loss only over text positions
        logits = logits[:, cfg.prefix_len:]
    mask = labels >= 0
    loss = cross_entropy(logits, jnp.maximum(labels, 0), mask)
    metrics = {"ce_loss": loss}
    if aux.get("moe_aux_loss") is not None and cfg.n_experts:
        loss = loss + 0.01 * aux["moe_aux_loss"] / max(cfg.n_layers, 1)
        metrics["moe_aux_loss"] = aux["moe_aux_loss"]
    metrics["loss"] = loss
    return loss, metrics


# ==========================================================================
# Decode: cache construction and single-token step
# ==========================================================================

def _zeros_factory(shape, dtype, axes):
    del axes
    return jnp.zeros(shape, dtype)


def init_caches(cfg: ModelConfig, batch: int, cache_len: int, *,
                factory=_zeros_factory):
    """Decode-state pytree. `factory(shape, dtype, axes)` lets the dry-run
    build abstract ShapeDtypeStructs with NamedShardings instead of arrays
    (zero allocation)."""
    dt = _dt(cfg)
    dh, hk = cfg.head_dim_eff, cfg.n_kv_heads

    def kv(n_stack, width, rolling):
        mk = lambda s, d, a: factory((n_stack,) + s, d, ("layer",) + a)
        return att.KVCache(
            k=mk((batch, width, hk, dh), dt,
                 ("batch", "kv_seq", "kv_heads", None)),
            v=mk((batch, width, hk, dh), dt,
                 ("batch", "kv_seq", "kv_heads", None)),
            kpos=mk((width,), jnp.int32, ("kv_seq",)),
            rolling=rolling)

    def ssm(n_stack):
        mk = lambda s, d, a: factory((n_stack,) + s, d, ("layer",) + a)
        return ssm_mod.SSMState(
            h=mk((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                 jnp.float32, ("batch", "heads", None, None)),
            conv_x=mk((batch, 3, cfg.ssm_heads, cfg.ssm_head_dim), dt,
                      ("batch", None, "heads", None)),
            conv_B=mk((batch, 3, cfg.ssm_state), dt, ("batch", None, None)),
            conv_C=mk((batch, 3, cfg.ssm_state), dt, ("batch", None, None)))

    fam = cfg.family
    if fam in ("dense", "vlm") or (fam == "moe" and not cfg.global_every):
        rolling = cfg.attn_kind == "window" and 0 < cfg.window < cache_len
        width = min(cache_len, cfg.window) if rolling else cache_len
        return {"kv": kv(cfg.n_layers, width, rolling)}
    if fam == "moe":  # llama4 iRoPE
        period = cfg.global_every
        nsup = cfg.n_layers // period
        caches = {}
        for i, (is_global, _) in enumerate(cfg.sub_pattern()):
            if is_global:
                caches[f"sub{i}"] = kv(nsup, cache_len, rolling=False)
            else:
                w = min(cache_len, cfg.chunk)
                caches[f"sub{i}"] = kv(nsup, w, rolling=True)
        return caches
    if fam == "ssm":
        return {"ssm": ssm(cfg.n_layers)}
    if fam == "hybrid":
        return {"ssm": ssm(cfg.n_layers),
                "attn": kv(cfg.n_attn_apps, cache_len, rolling=False)}
    raise ValueError(f"{fam} has no decode step")


def cache_axes(cfg, batch, cache_len):
    """Logical-axes pytree matching init_caches (for dry-run shardings)."""
    return init_caches(cfg, batch, cache_len,
                       factory=lambda s, d, a: (s, d, a))


def _attn_decode_block(params, x, cache, pos, cfg, sharder, *, kind,
                       use_rope, rope_freqs, is_moe):
    dt = _dt(cfg)
    h = rmsnorm(x, params["ln1"])
    cache, a = att.attention_decode(
        params["attn"], h, cache, pos, n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_eff, compute_dtype=dt,
        rope_freqs=rope_freqs if use_rope else None, kind=kind,
        window=cfg.window, chunk=cfg.chunk, qk_norm=cfg.qk_norm,
        sharder=sharder)
    x = x + a.astype(x.dtype)
    h = rmsnorm(x, params["ln2"])
    if is_moe:
        f, _ = moe_mod.moe_apply(
            params["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=max(cfg.capacity_factor, 2.0),
            compute_dtype=dt, sharder=sharder)
    else:
        f = _mlp_apply(params["mlp"], h, cfg, dt)
    return cache, x + f.astype(x.dtype)


def decode_step(params, cfg: ModelConfig, caches, token, pos, *,
                sharder=None):
    """One-token decode. token: (B, 1) int32; pos: () int32 — position of
    the new token. Returns (new_caches, logits (B, vocab))."""
    sharder = sharder or Sharder(enabled=False)
    dt = _dt(cfg)
    x = params["embed"].astype(dt)[token]
    x = sharder(x, "batch", None, "act_embed")
    rope_freqs = att.init_rope(cfg.head_dim_eff, cfg.rope_theta)
    fam = cfg.family

    if fam in ("dense", "vlm") or (fam == "moe" and not cfg.global_every):
        kind = "causal" if fam == "vlm" else cfg.attn_kind
        is_moe = fam == "moe"

        def blk(x, pc):
            p, cache = pc
            cache, x = _attn_decode_block(
                p, x, cache, pos, cfg, sharder, kind=kind, use_rope=True,
                rope_freqs=rope_freqs, is_moe=is_moe)
            return x, cache

        x, newkv = _scan(blk, x, (params["blocks"], caches["kv"]), cfg)
        new_caches = {"kv": newkv}

    elif fam == "moe":  # llama4
        pattern = cfg.sub_pattern()

        def blk(x, pc):
            p, cs = pc
            outs = {}
            for i, (is_global, is_moe) in enumerate(pattern):
                c, x = _attn_decode_block(
                    p[f"sub{i}"], x, cs[f"sub{i}"], pos, cfg, sharder,
                    kind="causal" if is_global else "chunk",
                    use_rope=not is_global, rope_freqs=rope_freqs,
                    is_moe=is_moe)
                outs[f"sub{i}"] = c
            return x, outs

        subcaches = {k: caches[k] for k in caches}
        x, new_caches = _scan(blk, x, (params["blocks"], subcaches), cfg)

    elif fam == "ssm":
        def blk(x, pc):
            p, st = pc
            h = rmsnorm(x, p["ln"])
            y, st = ssm_mod.mamba2_decode(
                p["mamba"], h, st, n_heads=cfg.ssm_heads,
                head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
                compute_dtype=dt, sharder=sharder)
            return x + y.astype(x.dtype), st

        x, newssm = _scan(blk, x, (params["blocks"], caches["ssm"]), cfg)
        new_caches = {"ssm": newssm}

    elif fam == "hybrid":
        # static groups (see forward): attention at app boundaries only,
        # plain indexing into the stacked caches
        period = cfg.attn_every
        shared = params["shared_attn"]

        def blk(x, pc):
            p, st = pc
            h = rmsnorm(x, p["ln"])
            y, st = ssm_mod.mamba2_decode(
                p["mamba"], h, st, n_heads=cfg.ssm_heads,
                head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
                compute_dtype=dt, sharder=sharder)
            return x + y.astype(x.dtype), st

        new_attn, new_ssm = [], []
        for app in range(cfg.n_attn_apps):
            cache = jax.tree.map(lambda a: a[app], caches["attn"])
            cache, x = _attn_decode_block(
                shared, x, cache, pos, cfg, sharder, kind="causal",
                use_rope=True, rope_freqs=rope_freqs, is_moe=False)
            new_attn.append(cache)
            lo = app * period
            hi = min(lo + period, cfg.n_layers)
            blk_params = jax.tree.map(lambda a: a[lo:hi],
                                      params["blocks"])
            blk_ssm = jax.tree.map(lambda a: a[lo:hi], caches["ssm"])
            x, sts = _scan(blk, x, (blk_params, blk_ssm), cfg)
            new_ssm.append(sts)
        new_caches = {
            "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                                *new_ssm),
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_attn)}
    else:
        raise ValueError(fam)

    logits = _head(params, cfg, x, last_only=True)[:, 0]
    return new_caches, logits


# ==========================================================================
# Prefill: full forward that also materializes decode caches
# ==========================================================================

def prefill(params, cfg: ModelConfig, inputs, cache_len: int, *,
            sharder=None):
    """Process a prompt, return (caches, last-position logits)."""
    sharder = sharder or Sharder(enabled=False)
    logits, collected, _ = forward(params, cfg, inputs, sharder=sharder,
                                   collect_kv=True, last_only=True)
    fam = cfg.family

    def build_kv(kvs, width, rolling):
        k, v = kvs  # each (L, B, H, S, Dh)
        return jax.vmap(
            lambda kk, vv: att.cache_from_prefill(kk, vv, width, rolling)
        )(k, v)

    if fam in ("dense", "vlm") or (fam == "moe" and not cfg.global_every):
        rolling = cfg.attn_kind == "window" and 0 < cfg.window < cache_len
        width = min(cache_len, cfg.window) if rolling else cache_len
        caches = {"kv": build_kv(collected, width, rolling)}
    elif fam == "moe":
        caches = {}
        for i, (is_global, _) in enumerate(cfg.sub_pattern()):
            if is_global:
                caches[f"sub{i}"] = build_kv(collected[i], cache_len, False)
            else:
                w = min(cache_len, cfg.chunk)
                caches[f"sub{i}"] = build_kv(collected[i], w, True)
    elif fam == "ssm":
        caches = {"ssm": collected}
    elif fam == "hybrid":
        states, kvs = collected  # kvs already stacked per application
        caches = {"ssm": states, "attn": build_kv(kvs, cache_len, False)}
    else:
        raise ValueError(f"{fam} has no decode step")
    return caches, logits[:, -1]
