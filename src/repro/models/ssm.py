"""Mamba-2 / SSD (state-space duality, arXiv:2405.21060) block.

Training/prefill uses the chunked SSD algorithm: intra-chunk quadratic
(attention-like) term + inter-chunk state recurrence (lax.scan over
chunks). Decode is the constant-memory recurrent form — this is what makes
long_500k runnable for ssm/hybrid archs (DESIGN.md §Arch-applicability).

Multi-head layout: x is (B, S, H, P) with scalar decay A per head and a
single B/C group shared across heads (n_groups = 1, as in Mamba-2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import PSpec, rmsnorm

__all__ = ["mamba2_plan", "mamba2_apply", "mamba2_decode", "SSMState",
           "init_ssm_state"]

_CONV_W = 4  # causal conv width, as in Mamba-2


def mamba2_plan(d_model: int, n_heads: int, head_dim: int, state: int):
    """d_inner = n_heads * head_dim (expand factor folded into n_heads)."""
    return {
        "wz": PSpec((d_model, n_heads, head_dim),
                    ("embed", "heads", "head_dim"), "scaled"),
        "wx": PSpec((d_model, n_heads, head_dim),
                    ("embed", "heads", "head_dim"), "scaled"),
        "wB": PSpec((d_model, state), ("embed", "state"), "scaled"),
        "wC": PSpec((d_model, state), ("embed", "state"), "scaled"),
        "wdt": PSpec((d_model, n_heads), ("embed", "heads"), "scaled"),
        "dt_bias": PSpec((n_heads,), ("heads",), "zeros"),
        "A_log": PSpec((n_heads,), ("heads",), "zeros"),
        "D": PSpec((n_heads,), ("heads",), "ones"),
        "conv_x": PSpec((_CONV_W, n_heads, head_dim),
                        ("conv", "heads", "head_dim"), "scaled"),
        "conv_B": PSpec((_CONV_W, state), ("conv", "state"), "scaled"),
        "conv_C": PSpec((_CONV_W, state), ("conv", "state"), "scaled"),
        "norm": PSpec((n_heads, head_dim), ("heads", "head_dim"), "zeros"),
        "wo": PSpec((n_heads, head_dim, d_model),
                    ("heads", "head_dim", "embed"), "scaled"),
    }


class SSMState(NamedTuple):
    h: jnp.ndarray        # (B, H, P, N) recurrent state
    conv_x: jnp.ndarray   # (B, _CONV_W-1, H, P) conv tail
    conv_B: jnp.ndarray   # (B, _CONV_W-1, N)
    conv_C: jnp.ndarray   # (B, _CONV_W-1, N)


def init_ssm_state(batch, n_heads, head_dim, state, dtype=jnp.float32):
    return SSMState(
        h=jnp.zeros((batch, n_heads, head_dim, state), jnp.float32),
        conv_x=jnp.zeros((batch, _CONV_W - 1, n_heads, head_dim), dtype),
        conv_B=jnp.zeros((batch, _CONV_W - 1, state), dtype),
        conv_C=jnp.zeros((batch, _CONV_W - 1, state), dtype))


def _causal_conv(x, kernel):
    """x: (B, S, ...); kernel: (W, ...) depthwise causal conv + SiLU."""
    w = kernel.shape[0]
    acc = x * kernel[-1]
    for i in range(1, w):
        shifted = jnp.pad(x, ((0, 0), (i, 0)) + ((0, 0),) * (x.ndim - 2)
                          )[:, :-i or None][:, :x.shape[1]]
        acc = acc + shifted * kernel[w - 1 - i]
    return jax.nn.silu(acc)


def _segsum(x):
    """x: (..., L). out[..., i, j] = sum_{j < k <= i} x_k, lower-tri."""
    n = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    tri = jnp.tril(jnp.ones((n, n), bool), 0)
    return jnp.where(tri, seg, -jnp.inf)


def _ssd_scan(xdt, dtA, b_in, c_in, chunk: int, unroll=False):
    """Chunked SSD core.

    xdt: (B, S, H, P) inputs pre-multiplied by dt
    dtA: (B, S, H) per-step log-decay (dt * A, negative)
    b_in/c_in: (B, S, N)
    Returns y: (B, S, H, P), final_state: (B, H, P, N).
    """
    bsz, s, h, p = xdt.shape
    n = b_in.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    xc = xdt.reshape(bsz, c, chunk, h, p)
    ac = dtA.reshape(bsz, c, chunk, h).transpose(0, 1, 3, 2)  # (B,C,H,L)
    bc = b_in.reshape(bsz, c, chunk, n)
    cc = c_in.reshape(bsz, c, chunk, n)

    # intra-chunk (quadratic, attention-like)
    L = jnp.exp(_segsum(ac))                                  # (B,C,H,L,L)
    y_diag = jnp.einsum("bcln,bcmn,bchlm,bcmhp->bclhp", cc, bc, L, xc)

    # per-chunk states to pass across the boundary
    cum = jnp.cumsum(ac, axis=-1)                             # (B,C,H,L)
    decay_states = jnp.exp(cum[..., -1:] - cum)               # (B,C,H,L)
    states = jnp.einsum("bcln,bchl,bclhp->bchpn", bc, decay_states, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[..., -1])                       # (B,C,H)

    def step(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, p, n), xdt.dtype)
    hfinal, hprevs = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)),
        unroll=True if unroll else 1)
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)                  # (B,C,H,P,N)

    state_decay = jnp.exp(cum)                                # (B,C,H,L)
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", cc, hprevs, state_decay)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, hfinal


def mamba2_apply(params, x, *, n_heads, head_dim, state, chunk=128,
                 compute_dtype=jnp.bfloat16, sharder=None, unroll=False):
    """Full-sequence Mamba-2 block (train / prefill).

    x: (B, S, D) -> (B, S, D), final SSMState (for decode continuation).
    """
    dt_ = compute_dtype
    b, s, d = x.shape
    z = jnp.einsum("bsd,dhp->bshp", x.astype(dt_), params["wz"].astype(dt_))
    xi = jnp.einsum("bsd,dhp->bshp", x.astype(dt_), params["wx"].astype(dt_))
    bi = jnp.einsum("bsd,dn->bsn", x.astype(dt_), params["wB"].astype(dt_))
    ci = jnp.einsum("bsd,dn->bsn", x.astype(dt_), params["wC"].astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32),
                        params["wdt"].astype(jnp.float32))

    # keep pre-conv tails for decode continuation
    tail_x = xi[:, -(_CONV_W - 1):]
    tail_B = bi[:, -(_CONV_W - 1):]
    tail_C = ci[:, -(_CONV_W - 1):]
    xi = _causal_conv(xi, params["conv_x"].astype(dt_))
    bi = _causal_conv(bi, params["conv_B"].astype(dt_))
    ci = _causal_conv(ci, params["conv_C"].astype(dt_))
    if sharder is not None:
        xi = sharder(xi, "batch", None, "heads", None)
        z = sharder(z, "batch", None, "heads", None)

    dt = jax.nn.softplus(dt_raw + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (H,) < 0
    dtA = dt * A[None, None, :]                                # (B,S,H)

    xdt = (xi.astype(jnp.float32) * dt[..., None])
    # pad sequence to a chunk multiple with state-neutral steps
    # (dtA = 0 -> decay 1; xdt = 0 -> no state update)
    pad = (-s) % chunk
    if pad:
        padz = lambda a: jnp.pad(a, [(0, pad if i == 1 else 0)
                                     for i in range(a.ndim)])
        xdt_p, dtA_p, bi_p, ci_p = (padz(xdt), padz(dtA),
                                    padz(bi.astype(jnp.float32)),
                                    padz(ci.astype(jnp.float32)))
    else:
        xdt_p, dtA_p, bi_p, ci_p = (xdt, dtA, bi.astype(jnp.float32),
                                    ci.astype(jnp.float32))
    y, hfinal = _ssd_scan(xdt_p, dtA_p, bi_p, ci_p, chunk, unroll=unroll)
    y = y[:, :s]
    y = y + xi.astype(jnp.float32) * params["D"].astype(
        jnp.float32)[None, None, :, None]
    y = y.astype(dt_) * jax.nn.silu(z)
    y = rmsnorm(y, params["norm"])
    out = jnp.einsum("bshp,hpd->bsd", y.astype(dt_),
                     params["wo"].astype(dt_))

    ssm_state = SSMState(
        h=hfinal.astype(jnp.float32),
        conv_x=tail_x.astype(dt_),
        conv_B=tail_B.astype(dt_),
        conv_C=tail_C.astype(dt_))
    return out, ssm_state


def mamba2_decode(params, x, st: SSMState, *, n_heads, head_dim, state,
                  compute_dtype=jnp.bfloat16, sharder=None):
    """Single-token recurrent step. x: (B, 1, D) -> (B, 1, D), new state."""
    dt_ = compute_dtype
    b = x.shape[0]
    xt = x[:, 0]
    z = jnp.einsum("bd,dhp->bhp", xt.astype(dt_), params["wz"].astype(dt_))
    xi = jnp.einsum("bd,dhp->bhp", xt.astype(dt_), params["wx"].astype(dt_))
    bi = jnp.einsum("bd,dn->bn", xt.astype(dt_), params["wB"].astype(dt_))
    ci = jnp.einsum("bd,dn->bn", xt.astype(dt_), params["wC"].astype(dt_))
    dt_raw = jnp.einsum("bd,dh->bh", xt.astype(jnp.float32),
                        params["wdt"].astype(jnp.float32))

    # causal conv over (tail ++ current)
    def conv_step(tail, cur, kern):
        k = kern.astype(dt_)
        hist = jnp.concatenate([tail, cur[:, None]], axis=1)  # (B, W, ...)
        out = jnp.einsum("bw...,w...->b...", hist, k)
        return jax.nn.silu(out), hist[:, 1:]

    xi, ncx = conv_step(st.conv_x, xi, params["conv_x"])
    bi, ncb = conv_step(st.conv_B, bi, params["conv_B"])
    ci, ncc = conv_step(st.conv_C, ci, params["conv_C"])

    dt = jax.nn.softplus(dt_raw + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])                           # (B,H)

    xf = xi.astype(jnp.float32)
    bf = bi.astype(jnp.float32)
    h_new = (st.h * decay[..., None, None]
             + jnp.einsum("bhp,bn->bhpn", xf * dt[..., None], bf))
    y = jnp.einsum("bhpn,bn->bhp", h_new, ci.astype(jnp.float32))
    y = y + xf * params["D"].astype(jnp.float32)[None, :, None]
    y = y.astype(dt_) * jax.nn.silu(z)
    y = rmsnorm(y, params["norm"])
    out = jnp.einsum("bhp,hpd->bd", y.astype(dt_), params["wo"].astype(dt_))
    return out[:, None], SSMState(h_new, ncx, ncb, ncc)
