"""Async continuous-batching serve loop over `SkylineEngine`.

The engine answers synchronous calls; production skyline serving is a
request *stream* with deadlines. `ServeLoop` turns the engine into that
front-end with the dispatch-ahead shape proven by LLM serving stacks:

  intake  ->  admission  ->  coalesce  ->  pack+dispatch   (staging
                                            thread, never waits on the
                                            device)
                               device executes wave k
              completion thread observes wave k finishing while the
              staging thread is already packing wave k+1

* **Dispatch-ahead double buffering.** Up to ``depth`` waves are in
  flight: the staging thread stages (level-1 host pack) and dispatches
  wave k+1 while the device still executes wave k. Completion is
  observed by a separate thread that blocks on the wave's output
  buffers, so the staging thread never blocks on the device — host pack
  time hides behind device compute. ``depth=1`` disables the overlap
  (the A/B knob the `serving_latency` benchmark flips).

* **Cross-tenant feed coalescing.** Pending `SkylineStream.feed` work
  items whose streams lease from the same slab bucket fuse into ONE
  gather+insert+scatter dispatch per wave (`repro.serve.engine`'s
  `_wave_feed`) — bit-for-bit equal to feeding the streams serially.

* **Deadline-aware admission with load shedding.** Work items carry an
  absolute deadline (`time.monotonic` instant). The scheduler processes
  earliest-deadline-first, sheds items that the EWMA wave-time model
  says cannot meet their deadline (or *degrades* them — subsampling a
  query's data — when ``degrade=True``), and under queue overload sheds
  oldest-deadline-first until the backlog fits.

Every stream mutation happens on the staging thread, so streams need no
locks; the completion thread only blocks on device buffers and resolves
tickets. The loop never calls a blocking stream settle — overflow
promotion rides the engine's fully-async pending-record path, and
repeated overflows of the same slab slot inside one in-flight window
chain onto the live pending records wave over wave (`_wave_feed`
overlays every outstanding record in-program), so no serving code path
retains a sanctioned blocking read; `drain` remains the only explicit
settle, for shutdown and tests.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Sequence

import jax
import numpy as np

from repro.serve.api import SkylineRequest
from repro.serve.engine import (SkylineEngine, SkylineStream, _next_bucket,
                                _wave_feed)

__all__ = ["ServeLoop", "Ticket"]


class Ticket:
    """Future handed back by `ServeLoop.submit` / `ServeLoop.feed`.

    ``status`` is ``"pending"`` until the completion thread resolves it
    to ``"ok"`` (``result``/``latency`` are set; ``degraded`` marks a
    query answered on subsampled data to meet its deadline) or the
    admission controller resolves it to ``"shed"``.
    """

    __slots__ = ("kind", "request", "stream", "chunks", "masks",
                 "deadline", "submitted_at", "status", "result",
                 "latency", "degraded", "_event")

    def __init__(self, kind, *, request=None, stream=None, chunks=None,
                 masks=None, deadline=None, submitted_at=0.0):
        self.kind = kind            # "query" | "feed"
        self.request = request
        self.stream = stream
        self.chunks = chunks
        self.masks = masks
        self.deadline = deadline
        self.submitted_at = submitted_at
        self.status = "pending"
        self.result = None
        self.latency = None
        self.degraded = False
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> "Ticket":
        if not self._event.wait(timeout):
            raise TimeoutError("ticket not resolved in time")
        return self


class _Wave:
    """One in-flight dispatch: the tickets it answers, the device
    buffers whose readiness marks its completion, the wave-time model
    buckets it updates, and its clock."""

    __slots__ = ("tickets", "markers", "keys", "staged_at",
                 "dispatched_at")

    def __init__(self, tickets, markers, keys, staged_at, dispatched_at):
        self.tickets = tickets
        self.markers = markers
        self.keys = keys
        self.staged_at = staged_at
        self.dispatched_at = dispatched_at


_STOP = object()


class ServeLoop:
    """Continuous-batching front-end: feed it `SkylineRequest`s and
    stream feeds, get `Ticket` futures back.

    ``depth`` is the dispatch-ahead window (1 = no overlap);
    ``max_wave`` caps the work items fused per wave; ``max_queue``
    bounds the backlog (beyond it, oldest-deadline-first shedding);
    ``degrade`` lets at-risk queries run on subsampled data instead of
    being shed. Use as a context manager, or call `start`/`close`.
    """

    def __init__(self, engine: SkylineEngine, *, depth: int = 2,
                 max_wave: int = 8, max_queue: int = 1024,
                 degrade: bool = False, ewma_alpha: float = 0.25,
                 clock=time.monotonic):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if max_wave < 1:
            raise ValueError(f"max_wave must be >= 1, got {max_wave}")
        self.engine = engine
        self.depth = depth
        self.max_wave = max_wave
        self.max_queue = max_queue
        self.degrade = degrade
        self._alpha = ewma_alpha
        self._clock = clock
        self._queue: collections.deque[Ticket] = collections.deque()
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._inflight = 0
        self._stopping = False
        self._started = False
        self._done_q: collections.deque = collections.deque()
        self._done_ev = threading.Event()
        # streams with unresolved pending overflow records, polled by
        # the staging thread whenever it would otherwise sit idle
        self._watch: dict[int, SkylineStream] = {}
        # wave-time model for admission: a per-(d, dtype, rows-bucket)
        # EWMA table of dispatch->complete times, seeded from the
        # engine's calibration timings when `calibrate_shard_threshold`
        # ran (`engine.wave_time_hints`); `_ewma` is the catch-all
        # scalar for buckets with no entry yet
        self._ewma = 0.0
        self._ewma_tab: dict[tuple, float] = dict(
            getattr(engine, "wave_time_hints", {}) or {})
        # kernel-tuning sweep timings ("sweep/d=4/dtype=float32") give a
        # weak per-(d, dtype) floor for buckets calibration never saw
        self._tuning_floor: dict[tuple, float] = {}
        table = getattr(engine, "kernel_tuning", None)
        for key, entry in (getattr(table, "entries", None) or {}).items():
            parts = key.split("/")
            if parts[0] == "sweep" and len(parts) == 3:
                try:
                    d = int(parts[1].split("=")[1])
                    dt = parts[2].split("=")[1]
                except (IndexError, ValueError):
                    continue
                self._tuning_floor[(d, dt)] = entry.time_us * 1e-6
        self.stats = {"completed": 0, "shed": 0, "degraded": 0,
                      "waves": 0, "coalesced_feeds": 0,
                      "stage_overlap_s": 0.0}

    # -- lifecycle ---------------------------------------------------------

    def start_serving(self) -> "ServeLoop":
        if self._started:
            return self
        self._started = True
        self._stager = threading.Thread(target=self._stage_loop,
                                        name="skyline-serve-stage",
                                        daemon=True)
        self._completer = threading.Thread(target=self._complete_loop,
                                           name="skyline-serve-complete",
                                           daemon=True)
        self._stager.start()
        self._completer.start()
        return self

    def __enter__(self) -> "ServeLoop":
        return self.start_serving()

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Flush the backlog, wait for in-flight waves, stop threads."""
        if not self._started:
            return
        with self._work:
            self._stopping = True
            self._work.notify_all()
        self._stager.join()
        self._done_q.append(_STOP)
        self._done_ev.set()
        self._completer.join()
        self._started = False

    def drain(self) -> "ServeLoop":
        """Block until every accepted item has resolved (the sanctioned
        synchronization point — serving calls never wait)."""
        with self._work:
            self._work.wait_for(
                lambda: not self._queue and self._inflight == 0)
        return self

    # -- intake ------------------------------------------------------------

    def submit(self, request: SkylineRequest) -> Ticket:
        """Enqueue one skyline query; its optional ``deadline`` rides
        into admission control."""
        if not isinstance(request, SkylineRequest):
            raise TypeError("submit() takes a SkylineRequest")
        t = Ticket("query", request=request, deadline=request.deadline,
                   submitted_at=self._clock())
        self._enqueue(t)
        return t

    def feed(self, stream: SkylineStream,
             chunks: Sequence, *, masks: Sequence | None = None,
             deadline: float | None = None) -> Ticket:
        """Enqueue one stream feed; feeds for streams sharing a slab
        bucket coalesce into one wave dispatch."""
        items, mlist = stream._feed_args(chunks, masks)
        t = Ticket("feed", stream=stream, chunks=items, masks=mlist,
                   deadline=deadline, submitted_at=self._clock())
        self._enqueue(t)
        return t

    def _enqueue(self, t: Ticket) -> None:
        if not self._started:
            raise RuntimeError("serve loop is not running (use `with "
                               "ServeLoop(engine):` or call start())")
        with self._work:
            self._queue.append(t)
            self._work.notify_all()

    # -- staging thread ----------------------------------------------------

    def _stage_loop(self) -> None:
        while True:
            with self._work:
                # the dispatch-ahead gate sits BEFORE staging: with
                # depth=1 nothing is staged until the previous wave
                # fully completed (no overlap); with depth=k the host
                # stages wave k+1 while the device runs wave k. While
                # streams hold pending overflow records the wait wakes
                # on a short timeout so idle time drains them eagerly.
                self._work.wait_for(
                    lambda: (self._queue and self._inflight < self.depth)
                    or self._stopping,
                    timeout=(self._POLL_S if self._watch else None))
                if self._stopping and not self._queue:
                    return
                batch: list[Ticket] = []
                if self._queue and (self._inflight < self.depth
                                    or self._stopping):
                    batch = self._admit_locked()
                    if batch:
                        self._inflight += 1
            if not batch:
                self._poll_watched()
                continue
            wave = self._stage_once(batch)
            self._done_q.append(wave)
            self._done_ev.set()

    _POLL_S = 0.002  # idle pending-drain poll interval

    def _poll_watched(self) -> None:
        """Idle-time maintenance on the staging thread (the single
        stream mutator, so streams stay lock-free): non-blocking poll
        of every stream holding pending overflow records; each record
        is released — with the full-capacity sub-state it pins — as
        soon as the device has delivered its fits vector, instead of
        at the stream's next serving op."""
        for sid in list(self._watch):
            if not self._watch[sid].poll():
                del self._watch[sid]

    def _admit_locked(self) -> list[Ticket]:
        """Pop the next wave's work items, earliest deadline first;
        shed what the wave-time model says cannot make it (callers hold
        the lock)."""
        now = self._clock()
        if len(self._queue) > self.max_queue:
            # overload: shed oldest-deadline-first until the backlog
            # fits (items with no deadline are kept — they can wait)
            dated = sorted((t for t in self._queue
                            if t.deadline is not None),
                           key=lambda t: t.deadline)
            doomed = set()
            excess = len(self._queue) - self.max_queue
            for t in dated[:excess]:
                doomed.add(id(t))
                self._shed(t)
            self._queue = collections.deque(
                t for t in self._queue if id(t) not in doomed)
        order = sorted(self._queue,
                       key=lambda t: (t.deadline is None, t.deadline,
                                      t.submitted_at))
        batch: list[Ticket] = []
        for t in order[:self.max_wave]:
            self._queue.remove(t)
            est = now + self._wave_time(self._model_key(t)) \
                * (self._inflight + 1)
            if t.deadline is not None and est > t.deadline:
                if self.degrade and t.kind == "query" \
                        and t.request.data.shape[0] > 1:
                    # answer on every other row instead of not at all
                    t.request = dataclasses.replace(
                        t.request, data=np.asarray(t.request.data)[::2],
                        mask=(None if t.request.mask is None else
                              np.asarray(t.request.mask)[::2]))
                    t.degraded = True
                    self.stats["degraded"] += 1
                else:
                    self._shed(t)
                    continue
            batch.append(t)
        return batch

    def _shed(self, t: Ticket) -> None:
        t.status = "shed"
        self.stats["shed"] += 1
        t._event.set()

    # -- wave-time model ---------------------------------------------------

    def _model_key(self, t: Ticket) -> tuple:
        """The EWMA-table bucket of one work item: (d, dtype, rows
        bucket) — slot rows for stream feeds, the padded query-length
        bucket for queries (the same keys `engine.wave_time_hints`
        seeds)."""
        if t.kind == "feed":
            s = t.stream
            return (s.d, np.dtype(s.dtype).name, s.rows)
        data = t.request.data
        n, d = data.shape
        return (d, np.dtype(data.dtype).name,
                _next_bucket(n, self.engine.min_n_bucket))

    def _wave_time(self, key: tuple) -> float:
        """Modeled wave time for one bucket: its EWMA entry, falling
        back to the cross-bucket scalar, then to the kernel-tuning
        floor, until the bucket has history."""
        t = self._ewma_tab.get(key)
        if t is not None:
            return t
        if self._ewma:
            return self._ewma
        return self._tuning_floor.get(key[:2], 0.0)

    def _stage_once(self, batch: list[Ticket]) -> _Wave:
        """Pack and dispatch one wave WITHOUT waiting on the device:
        queries go through `SkylineEngine.submit_many` (one bucketed
        dispatch per group), same-bucket stream feeds fuse via
        `_wave_feed`. Returns the in-flight record whose markers the
        completion thread blocks on."""
        staged_at = self._clock()
        markers: list = []
        queries = [t for t in batch if t.kind == "query"]
        feeds = [t for t in batch if t.kind == "feed"]
        if queries:
            results = self.engine.submit_many(
                [t.request for t in queries])
            for t, (buf, st) in zip(queries, results):
                t.result = (buf, st)
                markers.append(buf.points)
        if feeds:
            waves: dict[tuple, list] = {}
            for t in feeds:
                s = t.stream
                s._maybe_resolve()  # promotions change the bucket key
                waves.setdefault((id(s.arena), s.rows, s.cap),
                                 []).append(t)
            for group in waves.values():
                parts = [(t.stream, t.chunks, t.masks) for t in group]
                wstats = _wave_feed(self.engine, parts)
                self.stats["coalesced_feeds"] += len(group) - 1
                # a stats leaf of the wave program: small, ready exactly
                # when the wave's arena update is, and — unlike the
                # arena leaves, which the NEXT wave consumes (buffer
                # donation) — never invalidated while in flight
                markers.append(wstats[sorted(wstats)[0]])
                for t in group:
                    t.result = t.stream.last_stats
                    if t.stream._pendings:
                        self._watch[id(t.stream)] = t.stream
        self.stats["waves"] += 1
        keys = sorted({self._model_key(t) for t in batch})
        return _Wave(batch, markers, keys, staged_at, self._clock())

    # -- completion thread -------------------------------------------------

    def _complete_loop(self) -> None:
        while True:
            while not self._done_q:
                self._done_ev.wait()
                self._done_ev.clear()
            wave = self._done_q.popleft()
            if wave is _STOP:
                return
            for m in wave.markers:
                jax.block_until_ready(m)
            done_at = self._clock()
            wave_time = done_at - wave.dispatched_at
            for t in wave.tickets:
                t.status = "ok"
                t.latency = done_at - t.submitted_at
                self.stats["completed"] += 1
                t._event.set()
            with self._work:
                self._ewma = (wave_time if self._ewma == 0.0 else
                              self._alpha * wave_time
                              + (1 - self._alpha) * self._ewma)
                for k in wave.keys:
                    prev = self._ewma_tab.get(k)
                    self._ewma_tab[k] = (
                        wave_time if prev is None else
                        self._alpha * wave_time
                        + (1 - self._alpha) * prev)
                self.stats["stage_overlap_s"] += max(
                    0.0, wave.dispatched_at - wave.staged_at)
                self._inflight -= 1
                self._work.notify_all()
