"""Batched multi-query skyline engine.

The serving regime (ROADMAP north star: many concurrent users) is many
small/medium skyline queries, where per-query dispatch overhead dominates
the quadratic dominance work the paper parallelizes. The engine amortizes
that overhead: Q independent queries — separate datasets, or
preference-scaled views of one dataset — are padded to a common size
bucket, stacked, and answered with **one** ``vmap``-over-queries
invocation of the fused partition+local+merge program
(`repro.core.parallel.fused_skyline_fn`), i.e. a single XLA dispatch for
the whole batch.

Compilation-cache friendliness: query count Q and query length N are both
rounded up to power-of-two buckets (with floors), so the number of
distinct compiled programs is bounded by #Q-buckets x #N-buckets per
config, regardless of the ragged sizes users submit. Padding rows and
padding queries are fully masked out; every stage of the pipeline is
mask-correct, so results are identical to per-query execution.

Typical use::

    engine = SkylineEngine(SkyConfig(strategy="sliced", p=8))
    results = engine.run([pts_a, pts_b, pts_c])       # ragged batch
    views = engine.run_scaled(pts, weights)           # (Q, d) preferences
    fronts = engine.member_masks([crit_a, crit_b])    # admission masks
"""

from __future__ import annotations

import functools
from collections.abc import Mapping
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.dominance import SENTINEL
from repro.core.parallel import SkyConfig, fused_skyline_fn
from repro.core.sfs import SkyBuffer
from repro.core.sfs import skyline_mask as _skyline_mask

__all__ = ["SkylineEngine"]


def _next_bucket(size: int, floor: int) -> int:
    """Smallest power of two >= max(size, floor)."""
    b = max(int(floor), 1)
    while b < size:
        b *= 2
    return b


@functools.lru_cache(maxsize=None)
def _batched_pipeline(cfg: SkyConfig):
    """jit(vmap(fused pipeline)) — one dispatch for a (Q, N, d) batch."""
    return jax.jit(jax.vmap(fused_skyline_fn(cfg)))


@functools.lru_cache(maxsize=None)
def _pack_fn(ns: tuple[int, ...], masked: tuple[bool, ...], nb: int, qb: int):
    """One jitted dispatch that pads Q ragged queries to (qb, nb, d).

    Padding rows (and whole padding queries beyond len(ns)) get SENTINEL
    points and mask False; queries without an explicit mask get an
    iota-based all-valid mask, so no per-query host-side ops are needed.
    When no query carries a mask the jitted fn takes only the points list
    (fewer args to flatten on the hot path).
    """
    any_masked = any(masked)

    def pack(pts_list, mask_list):
        d = pts_list[0].shape[1]
        dt = pts_list[0].dtype
        rows = jnp.arange(nb)
        pts_p, mask_p = [], []
        for i, (n_i, p_i) in enumerate(zip(ns, pts_list)):
            if n_i == nb:
                pts_p.append(p_i)
            else:
                pts_p.append(
                    jnp.full((nb, d), SENTINEL, dt).at[:n_i].set(p_i))
            valid = rows < n_i
            if masked[i]:
                valid = valid & jnp.zeros((nb,), jnp.bool_).at[:n_i].set(
                    mask_list[i])
            mask_p.append(valid)
        for _ in range(qb - len(ns)):
            pts_p.append(jnp.full((nb, d), SENTINEL, dt))
            mask_p.append(jnp.zeros((nb,), jnp.bool_))
        return jnp.stack(pts_p), jnp.stack(mask_p)

    if any_masked:
        return jax.jit(pack)
    packed = jax.jit(lambda pts_list: pack(pts_list, None))
    return lambda pts_list, mask_list: packed(pts_list)


@functools.lru_cache(maxsize=None)
def _unpack_fn(q: int):
    """One jitted dispatch that splits a stacked result pytree into q
    per-query pytrees (XLA multi-output beats q x leaf gather calls)."""
    return jax.jit(lambda tree: tuple(
        jax.tree.map(lambda x: x[i], tree) for i in range(q)))


class _SlicedStats(Mapping):
    """Per-query view of a batch's stats pytree, sliced on access.

    Stats are read far less often than result buffers (debug/monitoring),
    so the engine defers the q x n_keys slice dispatches until a caller
    actually looks."""

    def __init__(self, stats: dict[str, jnp.ndarray], idx: int):
        self._stats = stats
        self._idx = idx

    def __getitem__(self, key):
        return self._stats[key][self._idx]

    def __iter__(self):
        return iter(self._stats)

    def __len__(self):
        return len(self._stats)


@functools.partial(jax.jit, static_argnames=("impl",))
def _batched_member_mask(pts, masks, impl: str = "auto"):
    return jax.vmap(lambda p, m: _skyline_mask(p, m, impl=impl))(pts, masks)


class SkylineEngine:
    """Answers batches of independent skyline queries in one dispatch.

    Args:
      cfg: pipeline configuration shared by all queries of this engine.
      min_n_bucket / min_q_bucket: floors of the power-of-two size
        buckets for query length and query count.

    The engine is stateless between calls apart from counters
    (`queries_answered`, `batches_dispatched`) and jax's compilation
    caches, so one engine can serve concurrent callers.
    """

    def __init__(self, cfg: SkyConfig = SkyConfig(), *,
                 min_n_bucket: int = 64, min_q_bucket: int = 4):
        self.cfg = cfg
        self.min_n_bucket = min_n_bucket
        self.min_q_bucket = min_q_bucket
        self.queries_answered = 0
        self.batches_dispatched = 0

    # -- padding helpers ---------------------------------------------------

    def _group(self, items) -> dict[tuple, list[int]]:
        """Indices grouped by compatible batch key (d, dtype, N-bucket)."""
        groups: dict[tuple, list[int]] = {}
        for i, x in enumerate(items):
            n, d = x.shape
            kb = (d, jnp.dtype(x.dtype).name,
                  _next_bucket(n, self.min_n_bucket))
            groups.setdefault(kb, []).append(i)
        return groups

    def _pack(self, items, masks, idxs):
        """Pad+stack the queries at `idxs` in one jitted dispatch.
        Returns (pts (qb, nb, d), mask (qb, nb))."""
        ns = tuple(items[i].shape[0] for i in idxs)
        nb = _next_bucket(max(ns), self.min_n_bucket)
        qb = _next_bucket(len(idxs), self.min_q_bucket)
        masked = tuple(masks[i] is not None for i in idxs)
        mask_list = ([masks[i] for i in idxs] if any(masked) else None)
        return _pack_fn(ns, masked, nb, qb)(
            [items[i] for i in idxs], mask_list)

    def _keys_batch(self, keys, idxs, qb: int):
        """(qb, 2) stacked keys; `keys` is a (Q, 2) array or a list of
        PRNGKeys. Dummy padding queries get zero keys."""
        if isinstance(keys, jnp.ndarray) and keys.ndim == 2:
            sel = (keys if len(idxs) == keys.shape[0]
                   and list(idxs) == list(range(keys.shape[0]))
                   else keys[jnp.asarray(list(idxs))])
        else:
            sel = jnp.stack([keys[i] for i in idxs])
        pad = qb - len(idxs)
        if pad:
            sel = jnp.concatenate(
                [sel, jnp.zeros((pad,) + sel.shape[1:], sel.dtype)])
        return sel

    # -- main entry points -------------------------------------------------

    def run(self, queries: Sequence[jnp.ndarray], *,
            masks: Sequence[jnp.ndarray | None] | None = None,
            keys: Sequence[jax.Array] | None = None,
            ) -> list[tuple[SkyBuffer, dict[str, Any]]]:
        """Answer Q ragged queries; returns one (SkyBuffer, stats) each.

        Queries are grouped by (d, dtype, N-bucket); each group becomes a
        single vmapped invocation of the fused pipeline. Whenever no
        bucket overflows, results bit-match per-query `parallel_skyline`
        (padding is masked out end to end). Under bucket overflow both
        paths drop excess rows, but the derived per-bucket capacity is
        computed from the padded length, so *which* rows are dropped can
        differ from the unpadded per-query run — the per-query
        `bucket_overflow`/`overflow` flags report the condition either
        way.
        """
        q = len(queries)
        if q == 0:
            return []
        if masks is None:
            masks = [None] * q
        if keys is None:
            keys = jax.random.split(jax.random.PRNGKey(0), q)
        elif len(keys) != q:
            raise ValueError(f"got {len(keys)} keys for {q} queries")

        groups = self._group(queries)
        out: list[tuple[SkyBuffer, dict[str, Any]] | None] = [None] * q
        for (d, _, nb), idxs in groups.items():
            # pack (pad+stack, masked dummy queries fill the Q bucket —
            # the pipeline is exact on empty inputs), compute, and unpack
            # are one XLA dispatch each, so engine overhead stays O(1)
            # dispatches per batch rather than O(Q).
            pts_b, mask_b = self._pack(queries, masks, idxs)
            qb = pts_b.shape[0]
            keys_b = self._keys_batch(keys, idxs, qb)
            bufs, stats = _batched_pipeline(self.cfg)(pts_b, mask_b, keys_b)
            self.batches_dispatched += 1
            per_query = _unpack_fn(qb)(bufs)
            for j, i in enumerate(idxs):
                out[i] = (per_query[j], _SlicedStats(stats, j))
        self.queries_answered += q
        return out  # type: ignore[return-value]

    def _run_stacked(self, views: jnp.ndarray,
                     mask: jnp.ndarray | None, keys,
                     ) -> list[tuple[SkyBuffer, dict[str, Any]]]:
        """Same-shape (Q, N, d) views: pad to buckets and dispatch with
        O(1) device ops — no per-view Python loop."""
        q, n, d = views.shape
        qb = _next_bucket(q, self.min_q_bucket)
        nb = _next_bucket(n, self.min_n_bucket)
        pts_b = jnp.pad(views, ((0, qb - q), (0, nb - n), (0, 0)),
                        constant_values=SENTINEL)
        valid = jnp.ones((q, n), jnp.bool_) if mask is None else (
            jnp.broadcast_to(mask, (q, n)))
        mask_b = jnp.zeros((qb, nb), jnp.bool_).at[:q, :n].set(valid)
        if keys is None:
            keys_b = jax.random.split(jax.random.PRNGKey(0), qb)
        else:
            keys_b = self._keys_batch(keys, range(q), qb)
        bufs, stats = _batched_pipeline(self.cfg)(pts_b, mask_b, keys_b)
        self.batches_dispatched += 1
        self.queries_answered += q
        per_query = _unpack_fn(qb)(bufs)
        return [(per_query[j], _SlicedStats(stats, j)) for j in range(q)]

    def run_scaled(self, pts: jnp.ndarray, weights: jnp.ndarray, *,
                   mask: jnp.ndarray | None = None,
                   keys: Sequence[jax.Array] | None = None,
                   ) -> list[tuple[SkyBuffer, dict[str, Any]]]:
        """Q preference-scaled views of one dataset.

        ``weights`` is (Q, d) of positive per-attribute preference scales
        (smaller-is-better attributes stay smaller-is-better); view q is
        ``pts * weights[q]``. All views share one (N, d) shape and are
        built by one broadcast multiply, so the whole call is a single
        batched dispatch.
        """
        if weights.ndim != 2 or weights.shape[1] != pts.shape[1]:
            raise ValueError("weights must be (Q, d)")
        return self._run_stacked(pts[None, :, :] * weights[:, None, :],
                                 mask, keys)

    def run_subspace(self, pts: jnp.ndarray, dim_masks: jnp.ndarray, *,
                     mask: jnp.ndarray | None = None,
                     keys: Sequence[jax.Array] | None = None,
                     ) -> list[tuple[SkyBuffer, dict[str, Any]]]:
        """Q subspace-skyline views of one dataset.

        ``dim_masks`` is (Q, d) bool; view q computes the skyline w.r.t.
        only the selected attributes (ignored attributes are zeroed for
        every row, making them non-discriminating: equal values keep
        ``<=`` true and ``<`` false, so dominance is decided by the
        selected dims). Unlike per-dim monotone rescaling — which never
        changes skyline membership — subspace views yield genuinely
        different fronts per user. Views are built by one broadcast
        `where`, so the whole call is a single batched dispatch.
        """
        if dim_masks.ndim != 2 or dim_masks.shape[1] != pts.shape[1]:
            raise ValueError("dim_masks must be (Q, d) bool")
        return self._run_stacked(
            jnp.where(dim_masks[:, None, :], pts[None, :, :], 0.0),
            mask, keys)

    def member_masks(self, crits: Sequence[jnp.ndarray], *,
                     masks: Sequence[jnp.ndarray | None] | None = None,
                     ) -> list[jnp.ndarray]:
        """Skyline *membership masks* (input order) for Q criteria sets.

        The scheduler's admission path needs in-place membership, not the
        compacted buffer; this batches `skyline_mask` with the same
        padding/bucketing scheme.
        """
        q = len(crits)
        if q == 0:
            return []
        if masks is None:
            masks = [None] * q
        out: list[jnp.ndarray | None] = [None] * q
        for (d, _, nb), idxs in self._group(crits).items():
            pts_b, mask_b = self._pack(crits, masks, idxs)
            res = _batched_member_mask(pts_b, mask_b, impl=self.cfg.impl)
            self.batches_dispatched += 1
            for j, i in enumerate(idxs):
                out[i] = res[j, :crits[i].shape[0]]
        self.queries_answered += q
        return out  # type: ignore[return-value]
