"""Batched multi-query skyline engine.

The serving regime (ROADMAP north star: many concurrent users) is many
small/medium skyline queries, where per-query dispatch overhead dominates
the quadratic dominance work the paper parallelizes. The engine amortizes
that overhead: Q independent queries — separate datasets, or
preference-scaled views of one dataset — are padded to a common size
bucket, stacked, and answered with **one** invocation of the fused
partition+local+merge program, i.e. a single XLA dispatch for the whole
batch.

Dispatch is two-path. Small-query buckets go through plain
vmap-over-queries of the single-device program. When the engine holds a
2-D ``(queries, workers)`` mesh, buckets whose padded length reaches
``shard_threshold_n`` route through the sharded batch program
(`repro.core.parallel.fused_skyline_batch_fn`): the query batch is
sharded over the ``queries`` mesh axis and each query's partitions over
the ``workers`` axis, so large queries engage every device instead of
serializing on one. Both paths run identical comparison/selection math
and return bit-for-bit equal results.

Compilation-cache friendliness: query count Q and query length N are both
rounded up to power-of-two buckets (with floors), so the number of
distinct compiled programs is bounded by #Q-buckets x #N-buckets per
config, regardless of the ragged sizes users submit. Packing is
two-level: level 1 copies each ragged query into a host-side staging
buffer (exact ragged shapes never reach XLA), level 2 is one jitted
finalize per size bucket — so adversarial raggedness cannot grow the
compile cache beyond the bucket count (`pack_trace_count` observes this).
Padding rows and padding queries are fully masked out; every stage of the
pipeline is mask-correct, so results are identical to per-query
execution.

Streaming: `open_stream` returns a `SkylineStream` — Q live skylines
advanced with one `feed` dispatch per arriving chunk batch and snapshot
at any time via `snapshot()`, bit-for-bit equal to re-running the whole
(unexpired) history through `run`. Stream states live in the engine's
shared slab allocator (`repro.serve.slab`): one device-resident arena
per (d, dtype, epochs, slot-rows) bucket, tenants lease front-sized
slots, and gather+insert+scatter fuse into one jitted program per
bucket — device buffers are O(#buckets), never O(#streams). With
``window_epochs=E`` the streams are sliding windows over an epoch ring
(repro.core.windowed): `tick()` ages all Q windows in one O(1)
dispatch and `snapshot` merges the ring on read. Chunks go through the
same two-level host-staged pack, so the insert compile cache is bounded
by the chunk-size buckets, never by the exact ragged arrival sizes.

Typical use::

    engine = SkylineEngine(SkyConfig(strategy="sliced", p=8))
    buf, stats = engine.submit(SkylineRequest(data=pts))
    results = engine.submit_many(
        [SkylineRequest(data=pts_a),                  # ragged batch
         SkylineRequest(data=pts, scale=weights[0]),  # preference view
         SkylineRequest(data=pts, subspace=dims[0])])
    fronts = engine.member_masks([crit_a, crit_b])    # admission masks

    stream = engine.open_stream(4, StreamOptions(q=2))  # 2 live skylines
    stream.feed([chunk_a0, chunk_b0])                 # one dispatch
    stream.feed([chunk_a1, None])                     # ragged arrivals
    (buf_a, buf_b) = stream.snapshot()                # canonical fronts

    mesh = make_engine_mesh(queries=2, workers=4)     # 8 devices
    engine = SkylineEngine(cfg, mesh=mesh, shard_threshold_n=4096)

The legacy per-family entry points (``run`` / ``run_scaled`` /
``run_subspace``, and ``open_stream``'s loose keyword knobs) remain as
thin deprecated wrappers over the request API, bit-for-bit equal to
``submit_many`` on the same inputs.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import sys
import time
import warnings
from collections.abc import Mapping
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import incremental, windowed
from repro.core import parallel as par
from repro.core.dominance import SENTINEL
from repro.core.parallel import SkyConfig, fused_skyline_batch_fn
from repro.core.sfs import SkyBuffer
from repro.core.sfs import skyline_mask as _skyline_mask
from repro.kernels.backend import resolve_spec
from repro.serve.api import SkylineRequest, StreamOptions
from repro.serve.slab import SlabArena, blank_leaf, slot_rows_bucket

__all__ = ["SkylineEngine", "SkylineStream", "SkylineRequest",
           "StreamOptions", "pack_trace_count",
           "calibrate_shard_threshold"]


def _next_bucket(size: int, floor: int) -> int:
    """Smallest power of two >= max(size, floor)."""
    b = max(int(floor), 1)
    while b < size:
        b *= 2
    return b


def _round_up(size: int, multiple: int) -> int:
    return -(-size // multiple) * multiple


# --------------------------------------------------------------------------
# Two-level bucketed pack
# --------------------------------------------------------------------------

# Traced-callback counter for the level-2 pack programs, mirroring
# repro.core.parallel.trace_count(): tests assert the pack compile cache
# stays bounded by the number of size buckets under ragged streams.
_PACK_EVENTS: collections.Counter[str] = collections.Counter()


def pack_trace_count() -> int:
    """How many distinct pack programs have been traced (bounded by the
    number of (Q-bucket, N-bucket, dtype, masked) combinations — never by
    the exact ragged sizes submitted)."""
    return _PACK_EVENTS["pack"]


@functools.lru_cache(maxsize=None)
def _pack_fn(nb: int, qb: int, d: int, dtype: str, masked: bool):
    """Level 2 of the bucketed pack: one jitted finalize per size bucket.

    Level 1 (`SkylineEngine._pack`) copies each ragged query into a
    host-side (qb, nb, d) staging buffer, so the exact ragged lengths
    reach this program only as *data* (the ``lengths`` vector), never as
    shapes: the cache key is the bucket, and the number of compiled pack
    programs is bounded by the number of size buckets no matter how
    adversarially ragged the submitted sizes are.
    """

    def finalize(stacked, lengths, user_mask):
        _PACK_EVENTS["pack"] += 1
        valid = jnp.arange(nb)[None, :] < lengths[:, None]
        if masked:
            valid = valid & user_mask
        return stacked, valid

    if masked:
        return jax.jit(finalize)
    fn = jax.jit(lambda stacked, lengths: finalize(stacked, lengths, None))
    return lambda stacked, lengths, user_mask: fn(stacked, lengths)


@functools.lru_cache(maxsize=None)
def _view_pack_fn(nb: int, qb: int, d: int, dtype: str, masked: bool,
                  kind: str):
    """Level 2 of the bucketed pack for *stacked views* of one dataset
    (`run_scaled` / `run_subspace`): one jitted finalize per size bucket.

    Level 1 stages the shared dataset into a host-side (nb, d) buffer and
    the per-view parameters into a (qb, d) buffer, so the exact (Q, N)
    reach this program only as data (the ``n_len`` / ``q_len`` scalars) —
    the compile cache is bounded by the bucket count under ragged
    multi-tenant shapes, exactly like `_pack_fn` (the eager per-shape
    ``jnp.pad`` this replaces compiled one program per exact (Q, N)).
    """

    def finalize(staged, n_len, q_len, params, user_mask):
        _PACK_EVENTS["pack"] += 1
        valid = ((jnp.arange(nb)[None, :] < n_len)
                 & (jnp.arange(qb)[:, None] < q_len))
        if masked:
            valid = valid & user_mask[None, :]
        if kind == "scale":
            views = staged[None, :, :] * params[:, None, :]
        else:  # subspace: ignored attributes zeroed (non-discriminating)
            views = jnp.where(params[:, None, :].astype(bool),
                              staged[None, :, :], 0.0)
        return jnp.where(valid[:, :, None], views, SENTINEL), valid

    if masked:
        return jax.jit(finalize)
    fn = jax.jit(lambda s, n, q, p: finalize(s, n, q, p, None))
    return lambda s, n, q, p, user_mask: fn(s, n, q, p)


@functools.lru_cache(maxsize=None)
def _unpack_fn(q: int):
    """One jitted dispatch that splits a stacked result pytree into q
    per-query pytrees (XLA multi-output beats q x leaf gather calls)."""
    return jax.jit(lambda tree: tuple(
        jax.tree.map(lambda x: x[i], tree) for i in range(q)))


class _SlicedStats(Mapping):
    """Per-query view of a batch's stats pytree, sliced on access.

    Stats are read far less often than result buffers (debug/monitoring),
    so the engine defers the q x n_keys slice dispatches until a caller
    actually looks."""

    def __init__(self, stats: dict[str, jnp.ndarray], idx: int):
        self._stats = stats
        self._idx = idx

    def __getitem__(self, key):
        return self._stats[key][self._idx]

    def __iter__(self):
        return iter(self._stats)

    def __len__(self):
        return len(self._stats)


@functools.partial(jax.jit, static_argnames=("impl",))
def _batched_member_mask(pts, masks, impl: str = "auto"):
    return jax.vmap(lambda p, m: _skyline_mask(p, m, impl=impl))(pts, masks)


class SkylineEngine:
    """Answers batches of independent skyline queries in one dispatch.

    Args:
      cfg: pipeline configuration shared by all queries of this engine.
      min_n_bucket / min_q_bucket: floors of the power-of-two size
        buckets for query length and query count.
      mesh: optional 2-D device mesh carrying `q_axis` and `w_axis`
        (see `repro.launch.mesh.make_engine_mesh`). Without one, every
        bucket uses the pure vmap path.
      shard_threshold_n: padded query length at which a bucket routes
        through the 2-D sharded program instead of plain vmap. Small
        queries stay on the vmap path — below the threshold the
        collective overhead of sharding exceeds the dominance work it
        divides.
      q_axis / w_axis: mesh axis names for the query batch and the
        per-query tuple partitions.

    The engine is stateless between calls apart from counters
    (`queries_answered`, `batches_dispatched`, `sharded_dispatched`) and
    jax's compilation caches, so one engine can serve concurrent callers.
    ``cfg.impl`` is resolved once at construction into ``kernel_spec``
    (repro.kernels.backend), so an unknown backend fails fast here.
    """

    def __init__(self, cfg: SkyConfig = SkyConfig(), *,
                 min_n_bucket: int = 64, min_q_bucket: int = 4,
                 mesh: jax.sharding.Mesh | None = None,
                 shard_threshold_n: int = 4096,
                 q_axis: str = "queries", w_axis: str = "workers",
                 min_slab_rows: int = 64):
        if mesh is not None:
            missing = {q_axis, w_axis} - set(mesh.axis_names)
            if missing:
                raise ValueError(
                    f"mesh lacks engine axes {sorted(missing)}; "
                    f"has {mesh.axis_names}")
        # resolve the kernel backend once, up front: an unknown
        # `cfg.impl` fails at engine construction, not mid-dispatch
        self.kernel_spec = resolve_spec(cfg.impl)
        self.cfg = cfg
        self.min_n_bucket = min_n_bucket
        self.min_q_bucket = min_q_bucket
        self.mesh = mesh
        self.shard_threshold_n = shard_threshold_n
        self.q_axis = q_axis
        self.w_axis = w_axis
        self.min_slab_rows = min_slab_rows
        # per-bucket (queries x workers) mesh factorings, set by
        # `calibrate_shard_threshold(..., factorings=True)`: bucket nb ->
        # (qa, wa, merge-mode). Buckets without an entry use the
        # constructor mesh; the merge-mode column resolves cfg.merge ==
        # 'auto' per topology (flat all_gather union vs the log2(W)-round
        # pruning ppermute tree — see repro.core.parallel.merge_stage).
        self.factorings: dict[int, tuple[int, int, str]] = {}
        self._fact_meshes: dict[tuple[int, int], jax.sharding.Mesh] = {}
        # measured wave times from `calibrate_shard_threshold`, keyed
        # (d, dtype-name, n-bucket): seeds `ServeLoop`'s per-bucket
        # EWMA admission model so the first waves after startup are
        # admitted against data rather than a cold scalar
        self.wave_time_hints: dict[tuple, float] = {}
        # shared slab arenas: tenant stream states lease slots from ONE
        # device-resident arena per (d, dtype, epochs, slot-rows) bucket
        self._arenas: dict[tuple, SlabArena] = {}
        # calibrated kernel geometry (`repro.kernels.tuning`): set by
        # `calibrate_kernels(engine)`; None falls back to the process
        # default table (env REPRO_KERNEL_TUNING)
        self.kernel_tuning = None
        # union-size histogram: observed per-stream per-epoch front
        # sizes, keyed (d, epochs) -> Counter{size: occurrences}.
        # Recorded off the hot path (stream counters()/close()) and
        # consulted by `open_stream` to auto-size `epoch_capacity`
        # when the StreamOptions knob is left unset.
        self.epoch_front_hist: dict[tuple[int, int],
                                    collections.Counter] = {}
        self.queries_answered = 0
        self.batches_dispatched = 0
        self.sharded_dispatched = 0

    # -- dispatch planning -------------------------------------------------

    def _use_sharded(self, nb: int) -> bool:
        return self.mesh is not None and nb >= self.shard_threshold_n

    def _mesh_for(self, nb: int | None) -> jax.sharding.Mesh | None:
        """The 2-D mesh a size-``nb`` bucket routes through: the
        calibrated per-bucket factoring when one was measured
        (`calibrate_shard_threshold`), else the constructor mesh."""
        if self.mesh is None:
            return None
        fact = None if nb is None else self.factorings.get(nb)
        if fact is None:
            return self.mesh
        qw = fact[:2]
        m = self._fact_meshes.get(qw)
        if m is None:
            from repro.launch.mesh import make_engine_mesh
            m = make_engine_mesh(qw[0], qw[1], q_axis=self.q_axis,
                                 w_axis=self.w_axis)
            self._fact_meshes[qw] = m
        return m

    def _merge_mode_for(self, nb: int | None) -> str | None:
        """The calibrated merge topology of a bucket's factoring, or
        None when the bucket was never measured (cfg.merge == 'auto'
        then falls through to the modeled-bytes resolution inside
        `repro.core.parallel.merge_stage`)."""
        fact = None if nb is None else self.factorings.get(nb)
        return fact[2] if fact is not None and len(fact) > 2 else None

    def _q_bucket(self, q: int, sharded: bool, nb: int | None = None) -> int:
        """Padded query count: power-of-two bucket, and on the sharded
        path additionally a multiple of the queries-axis size."""
        floor = self.min_q_bucket
        if sharded:
            nq = self._mesh_for(nb).shape[self.q_axis]
            return _round_up(_next_bucket(q, max(floor, nq)), nq)
        return _next_bucket(q, floor)

    def _pipeline(self, sharded: bool, nb: int | None = None,
                  cfg: SkyConfig | None = None):
        cfg = self.cfg if cfg is None else cfg
        if sharded:
            if cfg.merge == "auto":
                mode = self._merge_mode_for(nb)
                if mode is not None:
                    cfg = dataclasses.replace(cfg, merge=mode)
            return fused_skyline_batch_fn(cfg, self._mesh_for(nb),
                                          self.q_axis, self.w_axis)
        return fused_skyline_batch_fn(cfg)

    def _cfg_for(self, impl: str | None, d: int | None = None,
                 dtype=None) -> SkyConfig:
        """The engine config with a per-request kernel-backend override
        applied (requests without one share `self.cfg`, and with it the
        compile cache), then the calibrated kernel geometry.

        The (block, wtile) tuning table (`repro.kernels.tuning`) is
        consulted only for what the user left open: ``cfg.impl`` must be
        'auto' with no per-request override, and ``cfg.wtile`` unset (an
        explicitly pinned tile always wins).  SkyConfig is value-equal,
        so two requests tuned to the same geometry share one compiled
        program."""
        cfg = self.cfg
        if impl is not None and impl != cfg.impl:
            resolve_spec(impl)
            return dataclasses.replace(cfg, impl=impl)
        if (cfg.impl == "auto" and cfg.wtile == 0 and d is not None):
            from repro.kernels.tuning import default_table, tuning_key
            table = self.kernel_tuning or default_table()
            if table is not None:
                entry = table.entries.get(
                    tuning_key("sweep", d, dtype or jnp.float32))
                if entry is not None and entry.bitwise_ok:
                    cfg = dataclasses.replace(cfg, block=entry.block,
                                              wtile=entry.wtile)
        return cfg

    # -- slab arenas -------------------------------------------------------

    def _arena(self, d: int, dtype, epochs: int, rows: int) -> SlabArena:
        """The shared arena for one (d, dtype, epochs, slot-rows) bucket
        — created on first use, then leased from by every stream of the
        bucket (device buffers stay O(#buckets), never O(#streams))."""
        key = (int(d), jnp.dtype(dtype).name, int(epochs), int(rows))
        arena = self._arenas.get(key)
        if arena is None:
            arena = self._arenas[key] = SlabArena(
                epochs=epochs, rows=rows, d=d, dtype=dtype,
                donate=self.cfg.donate)
        return arena

    def arena_report(self) -> dict[tuple, dict[str, int]]:
        """Per-bucket slab accounting (slots / leases / device buffers /
        bytes) — the O(#buckets) memory assertion reads this."""
        return {k: {"slots": a.capacity, "leased": a.leased,
                    "buffers": a.num_buffers(), "bytes": a.device_bytes(),
                    "grows": a.grows}
                for k, a in self._arenas.items()}

    # -- padding helpers ---------------------------------------------------

    def _group(self, items) -> dict[tuple, list[int]]:
        """Indices grouped by compatible batch key (d, dtype, N-bucket)."""
        groups: dict[tuple, list[int]] = {}
        for i, x in enumerate(items):
            n, d = x.shape
            kb = (d, jnp.dtype(x.dtype).name,
                  _next_bucket(n, self.min_n_bucket))
            groups.setdefault(kb, []).append(i)
        return groups

    def _pack(self, items, masks, idxs, qb: int):
        """Pad+stack the queries at `idxs` to (qb, nb, d) / (qb, nb).

        Level 1 of the bucketed pack: each query is copied into a numpy
        staging buffer at its exact length (a host-side memcpy — device
        queries sync once here), then a single bucket-keyed jitted
        finalize uploads the batch and builds the validity mask from the
        dynamic lengths vector. See `_pack_fn` for why this bounds the
        compile cache."""
        ns = [items[i].shape[0] for i in idxs]
        nb = _next_bucket(max(ns), self.min_n_bucket)
        d = items[idxs[0]].shape[1]
        dtype = jnp.dtype(items[idxs[0]].dtype)
        staged = np.full((qb, nb, d), SENTINEL, dtype)
        lengths = np.zeros((qb,), np.int32)
        any_masked = any(masks[i] is not None for i in idxs)
        user_mask = np.ones((qb, nb), bool) if any_masked else None
        for j, i in enumerate(idxs):
            staged[j, :ns[j]] = np.asarray(items[i])
            lengths[j] = ns[j]
            if any_masked and masks[i] is not None:
                user_mask[j, :ns[j]] = np.asarray(masks[i])
        return _pack_fn(nb, qb, d, dtype.name, any_masked)(
            staged, lengths, user_mask)

    def _keys_batch(self, keys, idxs, qb: int):
        """(qb, 2) stacked keys; `keys` is a (Q, 2) array or a list of
        PRNGKeys. Dummy padding queries get zero keys."""
        if isinstance(keys, jnp.ndarray) and keys.ndim == 2:
            sel = (keys if len(idxs) == keys.shape[0]
                   and list(idxs) == list(range(keys.shape[0]))
                   else keys[jnp.asarray(list(idxs))])
        else:
            sel = jnp.stack([keys[i] for i in idxs])
        pad = qb - len(idxs)
        if pad:
            sel = jnp.concatenate(
                [sel, jnp.zeros((pad,) + sel.shape[1:], sel.dtype)])
        return sel

    # -- main entry points (request-oriented) ------------------------------

    def submit(self, request: SkylineRequest,
               ) -> tuple[SkyBuffer, dict[str, Any]]:
        """Answer one `SkylineRequest` (see `submit_many`)."""
        return self.submit_many([request])[0]

    def submit_many(self, requests: Sequence[SkylineRequest],
                    ) -> list[tuple[SkyBuffer, dict[str, Any]]]:
        """Answer a mixed batch of `SkylineRequest`s, one (SkyBuffer,
        stats) each, in request order.

        Plain requests are grouped by (d, dtype, N-bucket, impl); each
        group becomes a single invocation of the batched pipeline —
        vmap-only for small buckets, the 2-D (queries x workers) sharded
        program for buckets at or above `shard_threshold_n` when the
        engine holds a mesh. View requests (``scale`` / ``subspace``)
        that share one ``data`` object stack their view parameters and
        go through the broadcast view pack, so Q views of one dataset
        stay a single dispatch. Whenever no bucket overflows, results
        bit-match per-query `parallel_skyline` (padding is masked out
        end to end); under bucket overflow both paths drop excess rows,
        and the per-query `bucket_overflow`/`overflow` flags report the
        condition either way.

        Requests without a ``key`` draw from one positional
        ``jax.random.split(PRNGKey(0), len(requests))`` default, so an
        all-plain, all-default batch is bit-for-bit the legacy
        ``run(queries)``. Deadlines are ignored here (the caller is
        already waiting) — the async serve loop enforces them.
        """
        reqs = list(requests)
        if not reqs:
            return []
        for r in reqs:
            if not isinstance(r, SkylineRequest):
                raise TypeError(f"submit_many wants SkylineRequest items, "
                                f"got {type(r).__name__}")
        out: list[tuple[SkyBuffer, dict[str, Any]] | None] = [None] * len(reqs)
        defaults = [None]

        def _key_for(i):
            if reqs[i].key is not None:
                return reqs[i].key
            if defaults[0] is None:
                defaults[0] = jax.random.split(jax.random.PRNGKey(0),
                                               len(reqs))
            return defaults[0][i]

        # plain requests, grouped by compatible batch key (+ backend)
        groups: dict[tuple, list[int]] = {}
        vgroups: dict[tuple, list[int]] = {}
        for i, r in enumerate(reqs):
            n, d = r.data.shape
            if r.view_kind is None:
                kb = (d, jnp.dtype(r.data.dtype).name,
                      _next_bucket(n, self.min_n_bucket), r.impl)
                groups.setdefault(kb, []).append(i)
            else:
                mk = id(r.mask) if r.mask is not None else None
                vgroups.setdefault((id(r.data), r.view_kind, mk, r.impl),
                                   []).append(i)
        for (d, dtn, nb, impl), idxs in groups.items():
            # pack (pad+stack, masked dummy queries fill the Q bucket —
            # the pipeline is exact on empty inputs), compute, and unpack
            # are one XLA dispatch each, so engine overhead stays O(1)
            # dispatches per batch rather than O(Q).
            sharded = self._use_sharded(nb)
            qb = self._q_bucket(len(idxs), sharded, nb)
            items = [reqs[i].data for i in idxs]
            masks = [reqs[i].mask for i in idxs]
            pts_b, mask_b = self._pack(items, masks, range(len(idxs)), qb)
            keys_b = self._keys_batch([_key_for(i) for i in idxs],
                                      range(len(idxs)), qb)
            bufs, stats = self._pipeline(
                sharded, nb, self._cfg_for(impl, d, dtn))(
                pts_b, mask_b, keys_b)
            self.batches_dispatched += 1
            self.sharded_dispatched += sharded
            per_query = _unpack_fn(qb)(bufs)
            for j, i in enumerate(idxs):
                out[i] = (per_query[j], _SlicedStats(stats, j))
        for (_, kind, _, impl), idxs in vgroups.items():
            r0 = reqs[idxs[0]]
            params = np.stack([np.asarray(reqs[i].scale if kind == "scale"
                                          else reqs[i].subspace)
                               for i in idxs])
            # the legacy all-default quirk (keys drawn per *bucket* row,
            # not per view) is preserved bit-for-bit for shim parity
            keys = (None if all(reqs[i].key is None for i in idxs)
                    else [_key_for(i) for i in idxs])
            res = self._run_stacked(
                r0.data, params, r0.mask, keys, kind,
                cfg=self._cfg_for(impl, r0.data.shape[1], r0.data.dtype))
            for j, i in enumerate(idxs):
                out[i] = res[j]
        self.queries_answered += len(reqs)
        return out  # type: ignore[return-value]

    def _run_stacked(self, pts: jnp.ndarray, params: jnp.ndarray,
                     mask: jnp.ndarray | None, keys, kind: str,
                     cfg: SkyConfig | None = None,
                     ) -> list[tuple[SkyBuffer, dict[str, Any]]]:
        """Q views of one (N, d) dataset through the two-level bucketed
        pack: the dataset and the (Q, d) view parameters are host-staged
        at their exact sizes, then one bucket-keyed jitted finalize
        builds the (qb, nb, d) view batch on device — the view broadcast
        and the padding are inside the same program, and the compile
        cache stays bounded by the size buckets no matter how ragged the
        submitted (Q, N) pairs are."""
        n, d = pts.shape
        q = params.shape[0]
        nb = _next_bucket(n, self.min_n_bucket)
        sharded = self._use_sharded(nb)
        qb = self._q_bucket(q, sharded, nb)
        dtype = jnp.dtype(pts.dtype)
        staged = np.full((nb, d), SENTINEL, dtype)
        staged[:n] = np.asarray(pts)
        params_b = np.zeros((qb, d),
                            np.bool_ if kind == "subspace" else dtype)
        params_b[:q] = np.asarray(params)
        user_mask = None
        if mask is not None:
            user_mask = np.zeros((nb,), bool)
            user_mask[:n] = np.asarray(jnp.broadcast_to(mask, (n,)))
        pts_b, mask_b = _view_pack_fn(nb, qb, d, dtype.name,
                                      mask is not None, kind)(
            staged, np.int32(n), np.int32(q), params_b, user_mask)
        if keys is None:
            keys_b = jax.random.split(jax.random.PRNGKey(0), qb)
        else:
            keys_b = self._keys_batch(keys, range(q), qb)
        bufs, stats = self._pipeline(sharded, nb, cfg)(pts_b, mask_b,
                                                       keys_b)
        self.batches_dispatched += 1
        self.sharded_dispatched += sharded
        per_query = _unpack_fn(qb)(bufs)
        return [(per_query[j], _SlicedStats(stats, j)) for j in range(q)]

    # -- legacy entry points (deprecated wrappers over the request API) ----

    def run(self, queries: Sequence[jnp.ndarray], *,
            masks: Sequence[jnp.ndarray | None] | None = None,
            keys: Sequence[jax.Array] | None = None,
            ) -> list[tuple[SkyBuffer, dict[str, Any]]]:
        """Deprecated: build `SkylineRequest`s and call `submit_many`.

        Kept as a thin wrapper (bit-for-bit equal to the request path,
        asserted by tests/test_serve_loop.py) for one release."""
        warnings.warn("SkylineEngine.run is deprecated; submit "
                      "SkylineRequest objects via submit()/submit_many()",
                      DeprecationWarning, stacklevel=2)
        q = len(queries)
        if q == 0:
            return []
        if masks is None:
            masks = [None] * q
        if keys is None:
            keys = jax.random.split(jax.random.PRNGKey(0), q)
        elif len(keys) != q:
            raise ValueError(f"got {len(keys)} keys for {q} queries")
        return self.submit_many([
            SkylineRequest(data=x, mask=m, key=keys[i])
            for i, (x, m) in enumerate(zip(queries, masks))])

    def run_scaled(self, pts: jnp.ndarray, weights: jnp.ndarray, *,
                   mask: jnp.ndarray | None = None,
                   keys: Sequence[jax.Array] | None = None,
                   ) -> list[tuple[SkyBuffer, dict[str, Any]]]:
        """Deprecated: Q preference-scaled views of one dataset
        (``weights`` is (Q, d) positive per-attribute scales); submit
        `SkylineRequest(data=pts, scale=w)` instead. The wrapper builds
        the requests — sharing one ``data`` object, so they stack into
        the same single broadcast dispatch as before."""
        warnings.warn("SkylineEngine.run_scaled is deprecated; submit "
                      "SkylineRequest(data=..., scale=...) via "
                      "submit()/submit_many()",
                      DeprecationWarning, stacklevel=2)
        if weights.ndim != 2 or weights.shape[1] != pts.shape[1]:
            raise ValueError("weights must be (Q, d)")
        return self._legacy_views(pts, weights, mask, keys, "scale")

    def run_subspace(self, pts: jnp.ndarray, dim_masks: jnp.ndarray, *,
                     mask: jnp.ndarray | None = None,
                     keys: Sequence[jax.Array] | None = None,
                     ) -> list[tuple[SkyBuffer, dict[str, Any]]]:
        """Deprecated: Q subspace-skyline views of one dataset
        (``dim_masks`` is (Q, d) bool; ignored attributes are zeroed,
        making them non-discriminating); submit
        `SkylineRequest(data=pts, subspace=m)` instead."""
        warnings.warn("SkylineEngine.run_subspace is deprecated; submit "
                      "SkylineRequest(data=..., subspace=...) via "
                      "submit()/submit_many()",
                      DeprecationWarning, stacklevel=2)
        if dim_masks.ndim != 2 or dim_masks.shape[1] != pts.shape[1]:
            raise ValueError("dim_masks must be (Q, d) bool")
        return self._legacy_views(pts, dim_masks, mask, keys, "subspace")

    def _legacy_views(self, pts, params, mask, keys, kind: str):
        rows = np.asarray(params)
        if keys is not None and len(keys) != rows.shape[0]:
            raise ValueError(f"got {len(keys)} keys for {rows.shape[0]} "
                             f"views")
        return self.submit_many([
            SkylineRequest(data=pts, mask=mask,
                           scale=rows[i] if kind == "scale" else None,
                           subspace=rows[i] if kind == "subspace" else None,
                           key=None if keys is None else keys[i])
            for i in range(rows.shape[0])])

    def member_masks(self, crits: Sequence[jnp.ndarray], *,
                     masks: Sequence[jnp.ndarray | None] | None = None,
                     ) -> list[jnp.ndarray]:
        """Skyline *membership masks* (input order) for Q criteria sets.

        The scheduler's admission path needs in-place membership, not the
        compacted buffer; this batches `skyline_mask` with the same
        padding/bucketing scheme.
        """
        q = len(crits)
        if q == 0:
            return []
        if masks is None:
            masks = [None] * q
        out: list[jnp.ndarray | None] = [None] * q
        for (d, _, nb), idxs in self._group(crits).items():
            qb = _next_bucket(len(idxs), self.min_q_bucket)
            pts_b, mask_b = self._pack(crits, masks, idxs, qb)
            res = _batched_member_mask(pts_b, mask_b, impl=self.cfg.impl)
            self.batches_dispatched += 1
            for j, i in enumerate(idxs):
                out[i] = res[j, :crits[i].shape[0]]
        self.queries_answered += q
        return out  # type: ignore[return-value]

    # -- streaming ---------------------------------------------------------

    def record_epoch_fronts(self, d: int, epochs: int, counts) -> None:
        """Fold observed per-epoch front sizes into the union-size
        histogram.  ``counts`` is the (q, epochs) per-epoch antichain
        sizes a stream's `counters`/`close` sync materialized; zero
        entries (never-opened ring slots) carry no sizing information
        and are dropped."""
        sizes = np.asarray(counts).reshape(-1)
        sizes = sizes[sizes > 0]
        if sizes.size == 0:
            return
        hist = self.epoch_front_hist.setdefault(
            (int(d), int(epochs)), collections.Counter())
        hist.update(int(s) for s in sizes)

    def suggest_epoch_capacity(self, d: int, epochs: int) -> int:
        """Data-derived ``epoch_capacity`` for a new (d, epochs)
        windowed stream, from the union-size histogram — 0 when there
        is no basis for a suggestion (measure, don't guess: fewer than
        8 observed epoch fronts means the default full-capacity slots
        stand).

        The suggestion is 2x the largest front ever observed for the
        bucket (headroom for drift), rounded up to the dominance block
        so the slot shape is a kernel-friendly one, and only returned
        at all when it actually shrinks the slots below the full state
        capacity."""
        hist = self.epoch_front_hist.get((int(d), int(epochs)))
        if hist is None or sum(hist.values()) < 8:
            return 0
        block = self.cfg.block
        sug = -(-2 * max(hist) // block) * block
        if sug >= incremental.state_capacity(self.cfg):
            return 0
        return sug

    def open_stream(self, d: int, options: StreamOptions | None = None,
                    **legacy) -> "SkylineStream":
        """Open ``options.q`` live skylines over ``d``-attribute tuples.

        All stream knobs travel in a validated `StreamOptions`
        (`repro.serve.api`); passing them as loose keywords (``q=``,
        ``window_epochs=``, ...) still works but is deprecated.

        The returned `SkylineStream` keeps its states in the engine's
        shared slab arena (one device-resident arena per bucket, leased
        slots per tenant — `repro.serve.slab`); every `feed` is one
        insert dispatch for all q streams, routed through the same
        vmap-vs-sharded policy as `submit_many` (chunk buckets at or
        above `shard_threshold_n` shard over the 2-D mesh).

        With ``window_epochs=E`` the streams are *sliding windows*: an
        epoch ring of E sub-states per stream (repro.core.windowed).
        ``stream.tick()`` opens a new epoch — per tenant or for every
        stream — in one dispatch (expiring the oldest epoch in O(1)
        once a tenant's ring is full) and `snapshot` merges the ring on
        read. Without it the window is unbounded (insert-only).

        ``epoch_capacity`` (windowed streams only) declares the
        expected per-epoch front size: slots are then sized and padded
        to it (rounded to the dominance block) instead of the full
        state capacity inside the fused feed — `repro.core.windowed`'s
        epoch-ring capacity semantics, now on the slab path too."""
        if legacy:
            if options is not None:
                raise ValueError("pass either a StreamOptions or legacy "
                                 "keywords, not both")
            unknown = set(legacy) - {"q", "dtype", "key", "window_epochs",
                                     "epoch_capacity"}
            if unknown:
                raise TypeError(f"open_stream got unexpected keywords "
                                f"{sorted(unknown)}")
            warnings.warn("open_stream(**knobs) is deprecated; pass "
                          "open_stream(d, StreamOptions(...))",
                          DeprecationWarning, stacklevel=2)
            options = StreamOptions(**legacy)
        elif options is None:
            options = StreamOptions()
        # the union-size histogram closes the sizing loop: a windowed
        # stream that left `epoch_capacity` unset gets the data-derived
        # suggestion (0 — i.e. full-capacity slots — until enough epoch
        # fronts of this (d, epochs) bucket have been observed)
        if options.window_epochs is not None and not options.epoch_capacity:
            sug = self.suggest_epoch_capacity(d, options.window_epochs)
            if sug:
                options = dataclasses.replace(options, epoch_capacity=sug)
        return SkylineStream(self, d=d, options=options)


# --------------------------------------------------------------------------
# Slab-fused stream programs: gather leased slots + insert + scatter the
# packed fronts back, ONE jitted dispatch per feed (and one per tick /
# snapshot), cached per bucket key — never per stream.
# --------------------------------------------------------------------------

def _gather_slots(leaves, idx):
    return tuple(a[idx] for a in leaves)


def _sub_of_epoch(gathered, heads, c: int):
    """The (B, rows)-packed per-slot target-epoch sub-states of gathered
    slots as a full-capacity batched `SkylineState` (rows padded to
    ``c``). ``heads`` is a traced (B,) epoch vector — per-tenant ring
    clocks — so one compiled program serves every mix of head
    positions."""
    take = jax.vmap(functools.partial(jax.lax.dynamic_index_in_dim,
                                      axis=0, keepdims=False))
    sub = incremental.SkylineState(*(take(a, heads) for a in gathered))
    points, mask = incremental._fit_rows(sub.points, sub.mask, c)
    return sub._replace(points=points, mask=mask)


def _put_epoch(gathered, sub: incremental.SkylineState, heads, rows: int):
    """Write a batched sub-state back into each slot's ``heads[i]`` ring
    slot, truncated to the slot's ``rows`` (callers guarantee the packed
    fronts fit — see the promotion path)."""
    sub = sub._replace(points=sub.points[:, :rows],
                       mask=sub.mask[:, :rows])
    put = jax.vmap(
        lambda a, v, h: jax.lax.dynamic_update_index_in_dim(a, v, h, 0))
    return tuple(put(a, v, heads)
                 for a, v in zip(gathered, tuple(sub)))


def _splice_pending(fitted, pend_leaves, pos, sel, eps):
    """Overlay a pending wave's per-slot inserted epoch states onto
    gathered slot leaves: for each slot with ``sel[i]``, the pending row
    ``pos[i]`` replaces ring slot ``eps[i]``. The pending state is the
    authoritative value for its (slot, epoch) whether or not the
    conditional scatter installed it — when it fit, the arena copy is
    bitwise the same content, so the overlay is idempotent."""
    psub = incremental.SkylineState(*(a[pos] for a in pend_leaves))
    c = fitted[0].shape[-2]
    p_pts, p_mask = incremental._fit_rows(psub.points, psub.mask, c)
    psub = psub._replace(points=p_pts, mask=p_mask)

    def splice(leaf, val):
        upd = jax.vmap(lambda a, v, e:
                       jax.lax.dynamic_update_index_in_dim(a, v, e, 0))(
            leaf, val, eps)
        return jnp.where(sel.reshape((-1,) + (1,) * (leaf.ndim - 1)),
                         upd, leaf)

    return tuple(splice(a, v) for a, v in zip(fitted, tuple(psub)))


@functools.lru_cache(maxsize=None)
def _slab_feed_fn(cfg: SkyConfig, rows: int, q: int,
                  mesh: jax.sharding.Mesh | None,
                  q_axis: str, w_axis: str, cap: int,
                  npend: int = 0):
    """One fused wave program per bucket: gather the leased slots of one
    or MORE streams sharing the bucket, run the batched per-tenant
    head-epoch insert, and scatter the packed fronts back — per slot
    conditionally, so a front outgrowing its ``rows`` slot leaves the
    arena untouched and the returned ``cap``-row state (the wave's
    *pending* record) drives the fully-async promotion path instead.
    ``q`` is the wave's tenant count (only the first q of the padded
    slot indices are written); ``cap`` is the epoch-slot row ceiling
    (`windowed.epoch_rows` — the full state capacity for unbounded
    streams), so windowed feeds with a declared ``epoch_capacity``
    never pad slots back to the full C rows inside the fused program.

    ``npend`` is the number of unresolved pending records chained into
    the wave: each is overlaid on the gathered head-epoch states before
    inserting, restricted per entry to the tenants whose recorded ring
    slot IS the head this feed inserts into (entries parked at other
    epochs stay pending and keep overlaying reads — they are simply not
    part of this feed's target epoch). This is what lets feeds chain on
    overflowing feeds — any number of them, at any ring position —
    without any host read of a deferred ``fits`` vector: alive record
    entries are disjoint per (slot, epoch) (a chained wave kills the
    superseded head entries), so overlay order is immaterial."""

    def run(leaves, idx, heads, pts, mask, keys, *pargs):
        par._TRACE_EVENTS["slab_feed"] += 1
        gathered = _gather_slots(leaves, idx)
        sub = _sub_of_epoch(gathered, heads, cap)
        for r in range(npend):
            p_leaves, p_pos, p_sel, p_eps = pargs[4 * r:4 * r + 4]
            psub = incremental.SkylineState(
                *(a[p_pos] for a in p_leaves))
            p_pts, p_mask = incremental._fit_rows(psub.points, psub.mask,
                                                  cap)
            psub = psub._replace(points=p_pts, mask=p_mask)
            sel = p_sel & (p_eps == heads)
            sub = incremental.SkylineState(*(
                jnp.where(sel.reshape((-1,) + (1,) * (a.ndim - 1)),
                          pa, a)
                for a, pa in zip(tuple(sub), tuple(psub))))
        sub2, stats = incremental._insert_batch(
            sub, pts, mask, keys, cfg=cfg, mesh=mesh, q_axis=q_axis,
            w_axis=w_axis)
        # a slot at the epoch-capacity ceiling can never outgrow it;
        # otherwise each tenant checks its own front (per-slot fits)
        fits = (jnp.ones((q,), jnp.bool_) if rows >= cap
                else sub2.count[:q] <= rows)
        updated = _put_epoch(gathered, sub2, heads, rows)
        out = tuple(
            a.at[idx[:q]].set(
                jnp.where(fits.reshape((q,) + (1,) * (a.ndim - 1)),
                          u[:q], g[:q]))
            for a, u, g in zip(leaves, updated, gathered))
        return out, sub2, fits, stats

    # the arena leaves are donated (single-owner: `_wave_feed` hands them
    # over via arena.leaves() and installs the aliased outputs with
    # set_leaves); the pending-record operands (*pargs) are NOT — their
    # sub-states are shared with snapshot/counters overlays until resolved
    return jax.jit(run, donate_argnums=(0,)) if cfg.donate else jax.jit(run)


@functools.lru_cache(maxsize=None)
def _slab_promote_fn(old_rows: int, new_rows: int, q: int):
    """Move q streams' slots to a bigger rows bucket: re-pad the old
    slot contents and splice in the pending wave's inserted epoch
    states (the full-``cap``-row results the per-slot conditional
    scatter withheld) at each tenant's recorded epoch. Returns the
    (q, E, new_rows, ...) slot values for the new arena."""

    def run(old_leaves, idx, eps, sub_leaves, pos, take):
        gathered = _gather_slots(old_leaves, idx)  # (q, E, old_rows, ..)
        points, mask = incremental._fit_rows(gathered[0], gathered[1],
                                             new_rows)
        gathered = (points, mask) + gathered[2:]
        sub = incremental.SkylineState(*(a[pos] for a in sub_leaves))
        spliced = _put_epoch(gathered, sub, eps, new_rows)
        return tuple(
            jnp.where(take.reshape((-1,) + (1,) * (s.ndim - 1)), s, g)
            for s, g in zip(spliced, gathered))

    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _slab_put_fn(q: int, donate: bool = True):
    def run(leaves, idx, vals):
        return tuple(a.at[idx].set(v) for a, v in zip(leaves, vals))
    return jax.jit(run, donate_argnums=(0,)) if donate else jax.jit(run)


@functools.lru_cache(maxsize=None)
def _slab_clear_epoch_fn(donate: bool = True):
    """Blank one epoch ring slot PER TENANT of a batch of leased slots
    (the O(1) expiry: nothing is recomputed, merge-on-read resolves the
    rest). ``epoch`` is a (q,) per-tenant slot vector and ``sel`` a
    (q,) bool mask — tenants outside the selection keep their ring
    untouched, so per-tenant clocks tick independently in one
    dispatch."""

    def run(leaves, idx, epoch, sel):
        par._TRACE_EVENTS["slab_tick"] += 1
        out = []
        for a in leaves:
            sub = a[idx]  # (q, E, ...)
            blank = blank_leaf(sub.shape[:1] + sub.shape[2:], a.dtype)
            upd = jax.vmap(lambda s, b, e:
                           jax.lax.dynamic_update_index_in_dim(s, b, e, 0)
                           )(sub, blank, epoch)
            upd = jnp.where(sel.reshape((-1,) + (1,) * (upd.ndim - 1)),
                            upd, sub)
            out.append(a.at[idx].set(upd))
        return tuple(out)

    return jax.jit(run, donate_argnums=(0,)) if donate else jax.jit(run)


@functools.lru_cache(maxsize=None)
def _slab_snapshot_fn(cfg: SkyConfig, rows: int, epochs: int,
                      npend: int = 0):
    """Canonical per-stream snapshot of leased slots in one dispatch:
    unbounded streams (E == 1) canonicalize their antichain directly;
    windowed streams merge the epoch ring on read (repro.core.windowed).
    The stream's ``npend`` unresolved pending wave records are overlaid
    first (`_splice_pending`, one per record — alive entries are
    disjoint per (slot, epoch), so order is immaterial), so a snapshot
    straight after an overflowing feed reads the true fronts WITHOUT
    any host-blocking resolve — the promotion decision keeps riding the
    async path."""
    c = incremental.state_capacity(cfg)

    def run(leaves, idx, *pargs):
        par._TRACE_EVENTS["slab_snapshot"] += 1
        gathered = _gather_slots(leaves, idx)
        points, mask = incremental._fit_rows(gathered[0], gathered[1], c)
        fitted = (points, mask) + gathered[2:]
        for r in range(npend):
            fitted = _splice_pending(fitted, *pargs[4 * r:4 * r + 4])
        points, mask, count, overflow, seen, chunks = fitted
        if epochs == 1:
            state = incremental.SkylineState(
                points[:, 0], mask[:, 0], count[:, 0], overflow[:, 0],
                seen[:, 0], chunks[:, 0])
            return jax.vmap(
                functools.partial(incremental._finalize, cfg=cfg))(state)
        wstate = windowed.WindowedSkylineState(
            points, mask, count, overflow, seen, chunks,
            head=jnp.int32(0), active=jnp.int32(epochs))
        return windowed._wfinalize_batch(wstate, cfg=cfg, mesh=None,
                                         q_axis="queries")

    # read-only overlay: the snapshot reads the live arena (and the
    # shared pending sub-states) that the next wave still consumes —
    # donating here would delete buffers another program owns
    # skylint: disable=R6
    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _slab_counters_fn(npend: int = 0):
    """Per-stream running stats over the live ring in one dispatch,
    pending-overlay-aware like the snapshot program."""

    def run(leaves, idx, *pargs):
        gathered = _gather_slots(leaves, idx)
        for r in range(npend):
            gathered = _splice_pending(gathered, *pargs[4 * r:4 * r + 4])
        _, _, count, overflow, seen, chunks = gathered
        # the raw (q, epochs) per-epoch antichain sizes ride along: the
        # engine's epoch-front histogram (auto-sized `epoch_capacity`)
        # feeds off them at the same single host sync
        return (jnp.sum(count, axis=1), jnp.sum(seen, axis=1),
                jnp.sum(chunks, axis=1), jnp.any(overflow, axis=1),
                count)

    # read-only overlay: stats ride the live arena + shared pending
    # sub-states without consuming them (same contract as the snapshot)
    # skylint: disable=R6
    return jax.jit(run)


class _Pending:
    """One wave's deferred slot-overflow record.

    The wave program returns the full-``cap``-row inserted head-epoch
    states (``sub``) and a per-slot device ``fits`` vector; nothing on
    the host ever *waits* for them. ``pos`` maps this stream's tenants
    into the wave arrays, ``epochs`` snapshots each tenant's ring slot
    at feed time, and ``alive`` tracks which entries are still the
    authoritative value for their (slot, epoch) — a tick that clears
    the recorded slot kills the entry, and a chained feed into the
    same slot supersedes it. A stream may hold several records at once
    (``SkylineStream._pendings``) — one per unresolved wave — with
    alive entries disjoint per (slot, epoch). Until the non-blocking
    poll (`SkylineStream._maybe_resolve`) finds a record's ``fits``
    ready, every read and every chained feed overlays it inside its
    jitted program; no serving operation ever blocks on the check."""

    __slots__ = ("sub", "fits", "pos", "epochs", "alive")

    def __init__(self, sub, fits, pos, epochs, alive):
        self.sub = sub
        self.fits = fits
        self.pos = pos
        self.epochs = epochs
        self.alive = alive


class _WaveStats(Mapping):
    """Per-stream view of a wave's stats pytree: rows [off, off+q) of
    each leaf, sliced on access (stats are read far less often than
    result buffers, so the slices stay deferred)."""

    def __init__(self, stats: dict[str, jnp.ndarray], off: int, q: int):
        self._stats = stats
        self._off = off
        self._q = q

    def __getitem__(self, key):
        return self._stats[key][self._off:self._off + self._q]

    def __iter__(self):
        return iter(self._stats)

    def __len__(self):
        return len(self._stats)


def _wave_feed(engine: SkylineEngine, parts) -> Mapping:
    """ONE coalesced gather+insert+scatter dispatch for the feeds of
    one or more `SkylineStream`s sharing a slab bucket (``parts`` is a
    list of (stream, items, masks)).

    This is the cross-tenant coalescing primitive of the serve loop:
    the members' chunks go through the level-1 host pack together, the
    slot indices / per-tenant ring heads concatenate into one wave, and
    the per-stream partitioning keys are derived exactly as the serial
    feed derives them — so a coalesced wave is bit-for-bit equal to
    feeding the members one by one. Each member's share of the wave's
    deferred fits record becomes its `_Pending`; the host never reads
    the device between waves (an async host copy of ``fits`` is merely
    *started* so the later poll finds it ready)."""
    for s, _, _ in parts:
        s._maybe_resolve()
    groups: dict[tuple, list] = {}
    for part in parts:
        s = part[0]
        groups.setdefault((id(s.arena), s.rows, s.cap), []).append(part)
    if len(groups) > 1:
        # an opportunistic promotion just split the bucket: dispatch
        # each sub-bucket as its own wave
        stats = None
        for group in groups.values():
            stats = _wave_feed(engine, group)
        return stats
    s0 = parts[0][0]
    arena, rows, cap = s0.arena, s0.rows, s0.cap
    total = sum(p[0].q for p in parts)
    wb = engine._q_bucket(total, engine.mesh is not None)
    items: list = []
    masks: list = []
    idx: list[int] = []
    heads: list[int] = []
    key_rows = []
    for s, its, ms in parts:
        items += its
        masks += ms
        idx += list(map(int, s._idx()))  # raises if the stream closed
        heads += [int(h) for h in s._head]
        # per-stream key derivation matches the serial feed bit-for-bit
        key_rows.append(jax.random.split(
            jax.random.fold_in(jnp.asarray(s._key), s.chunks_fed),
            s.qb)[:s.q])
    pts_b, mask_b = engine._pack(items, masks, range(total), wb)
    nb = pts_b.shape[1]
    sharded = engine._use_sharded(nb)
    keys_b = (key_rows[0] if len(key_rows) == 1
              else jnp.concatenate(key_rows))
    pad = wb - total
    if pad:
        keys_b = jnp.concatenate(
            [keys_b, jnp.zeros((pad,) + keys_b.shape[1:], keys_b.dtype)])
    # chain EVERY unresolved record of every member into the program —
    # the wave-chaining fast path: a second (third, ...) overflow of the
    # same slab slot inside one in-flight window overlays the live
    # record per entry, and records parked at non-head epochs by a tick
    # simply ride along untouched. Records shared by several members
    # (from an earlier coalesced wave) are deduped by their fits buffer
    # and enter the program once, with the members' entries merged.
    recs: dict[int, tuple[tuple, list]] = {}
    off = 0
    for s, _, _ in parts:
        for p in s._pendings:
            if p.alive.any():
                recs.setdefault(id(p.fits), (tuple(p.sub), []))[1].append(
                    (off, s.q, p))
        off += s.q
    pargs: list = []
    for sub, members in recs.values():
        p_pos = np.zeros((wb,), np.int32)
        p_sel = np.zeros((wb,), bool)
        p_eps = np.zeros((wb,), np.int32)
        for off_s, sq, p in members:
            p_pos[off_s:off_s + sq] = p.pos
            p_sel[off_s:off_s + sq] = p.alive
            p_eps[off_s:off_s + sq] = p.epochs
        pargs += [sub, p_pos, p_sel, p_eps]
    fn = _slab_feed_fn(engine.cfg, rows, total,
                       engine.mesh if sharded else None, engine.q_axis,
                       engine.w_axis, cap, len(recs))
    idx_np = np.asarray(idx + [idx[0]] * pad, np.int32)
    heads_np = np.asarray(heads + [heads[0]] * pad, np.int32)
    new_leaves, sub2, fits, stats = fn(arena.leaves(), idx_np, heads_np,
                                       pts_b, mask_b, keys_b, *pargs)
    arena.set_leaves(new_leaves)
    sub2 = tuple(sub2)
    if rows < cap:
        # start the deferred per-slot fits on its way to the host so
        # the later non-blocking poll finds it ready
        fits.copy_to_host_async()
    off = 0
    for s, _, _ in parts:
        # this wave's write supersedes the chained head-epoch entries:
        # whether the scatter installed it or the new record carries it,
        # the old records are no longer authoritative for the head slot
        for p in s._pendings:
            p.alive &= ~(p.epochs == s._head)
        s._pendings = [p for p in s._pendings if p.alive.any()]
        if rows < cap:
            s._pendings.append(_Pending(
                sub=sub2, fits=fits,
                pos=np.arange(off, off + s.q, dtype=np.int32),
                epochs=s._head.copy(),
                alive=np.ones((s.q,), bool)))
        s.last_stats = _WaveStats(stats, off, s.q)
        s.chunks_fed += 1
        off += s.q
    engine.batches_dispatched += 1
    engine.sharded_dispatched += sharded
    return stats


class SkylineStream:
    """Q live skylines fed incrementally through a `SkylineEngine`.

    Arriving chunks are ragged per stream and per feed; they go through
    the engine's two-level host-staged pack into (qb, nb) size buckets,
    so both the pack and the insert compile caches stay bounded by the
    bucket count no matter how chunk sizes drift.

    States live in the engine's shared slab arena (`repro.serve.slab`):
    the stream leases one slot per live skyline from the arena of its
    (d, dtype, epochs, slot-rows) bucket, so a fleet of tenant streams
    shares O(#buckets) device buffers and each tenant's resident
    footprint is its slot's row count — a power-of-two tracking its
    *front* size, promoted to the next bucket when the front outgrows it
    — not the engine's full C-row state capacity. Every `feed` fuses
    gather + insert + scatter into one dispatch (and the serve loop
    coalesces feeds of multiple streams sharing a bucket into one wave
    — `_wave_feed`); `snapshot` returns canonical per-stream
    `SkyBuffer`s bit-for-bit equal to one-shot recomputation over the
    unexpired history (repro.core.incremental / repro.core.windowed).

    NO stream operation blocks on the device. When a front outgrows its
    slot, the wave program withholds that slot's scatter and returns
    the full inserted state as a *pending record*; reads and chained
    feeds overlay the record inside their jitted programs, and the
    stream is promoted to a bigger rows bucket only once a non-blocking
    poll finds the deferred per-slot ``fits`` vector already delivered
    (`_maybe_resolve`; `drain()` is the explicit blocking settle for
    shutdown and tests).

    With ``window_epochs=E`` the streams are sliding windows over an
    epoch ring: `tick()` opens a new epoch — for all q tenants or any
    subset — in one dispatch (a full ring expires its oldest epoch in
    O(1)), `expire_epoch()` drops tails without opening one, and
    `snapshot` merges the ring on read. Each tenant has its OWN ring
    clock (head/active vectors, host-side) — the clocks enter the
    compiled programs as data, so one compiled feed serves every mix of
    head positions.
    """

    def __init__(self, engine: SkylineEngine, *, d: int,
                 options: StreamOptions | None = None):
        if options is None:
            options = StreamOptions()
        self.engine = engine
        self.options = options
        self.q = options.q
        self.d = d
        self.dtype = jnp.dtype(options.dtype)
        self.window_epochs = options.window_epochs
        self.epochs = int(options.window_epochs or 1)
        self.epoch_capacity = int(options.epoch_capacity)
        # fixed Q bucket compatible with BOTH dispatch paths: with a mesh
        # it is a multiple of the queries-axis size, so any chunk bucket
        # may route sharded without reshaping the state
        self.qb = engine._q_bucket(self.q, engine.mesh is not None)
        # the slot-row ceiling: epoch_capacity (rounded to the dominance
        # block) for windowed streams that declared one, else the full
        # state capacity — promotions stop at it, and the fused feed
        # pads slots only up to it
        self.cap = windowed.epoch_rows(engine.cfg, self.epoch_capacity)
        self.rows = slot_rows_bucket(1, engine.min_slab_rows, self.cap)
        self.arena = engine._arena(d, self.dtype, self.epochs, self.rows)
        self.slots = self.arena.lease(self.q)
        # previous waves' deferred per-slot fits records (oldest first),
        # settled asynchronously — see `_maybe_resolve`. Alive entries
        # are disjoint per (slot, epoch): a chained feed kills the
        # superseded head entries, a tick kills the cleared slot's.
        self._pendings: list[_Pending] = []
        # per-tenant ring clocks (host-side int vectors; traced as
        # data, never as shapes)
        self._head = np.zeros((self.q,), np.int32)
        self._active = np.ones((self.q,), np.int32)
        # the seed key is stored host-side (an idle stream must hold NO
        # device buffers — np.asarray would alias the jax buffer and
        # keep it alive, so copy). New-style typed keys are stored as
        # their raw bits and re-derived through the legacy impl — keys
        # only seed the partitioning here, any deterministic stream is
        # valid.
        key = options.key
        if key is None:
            self._key = np.zeros((2,), np.uint32)
        else:
            if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
                key = jax.random.key_data(key)
            self._key = np.array(key, copy=True)
        self.chunks_fed = 0
        self.ticks = 0
        self.last_stats: Mapping | None = None

    @property
    def windowed(self) -> bool:
        return self.window_epochs is not None

    def _idx(self, padded: bool = False) -> np.ndarray:
        if not self.slots:
            raise ValueError("stream is closed (slots released)")
        slots = self.slots
        if padded:  # fill the Q bucket by repeating slot 0 (reads only)
            slots = slots + [slots[0]] * (self.qb - self.q)
        return np.asarray(slots, np.int32)

    def _tenant_sel(self, tenants) -> np.ndarray:
        if tenants is None:
            return np.ones((self.q,), bool)
        sel = np.zeros((self.q,), bool)
        for t in tenants:
            t = int(t)
            if not 0 <= t < self.q:
                raise ValueError(f"tenant {t} out of range for "
                                 f"q={self.q}")
            sel[t] = True
        if not sel.any():
            raise ValueError("need at least one tenant")
        return sel

    def _pend_args(self) -> tuple:
        """Flattened (pend leaves, pos, sel, epochs) program arguments,
        four per unresolved pending record (may be empty)."""
        out: list = []
        for p in self._pendings:
            if p.alive.any():
                out += [tuple(p.sub), p.pos, p.alive, p.epochs]
        return tuple(out)

    # -- async pending settlement ------------------------------------------

    def _maybe_resolve(self) -> None:
        """Settle deferred per-slot fits checks WITHOUT blocking: the
        wave program computes ``fits`` on device and `_wave_feed`
        starts an async host copy; this poll settles exactly the
        records whose vector the device has delivered on its own
        (records resolve independently — their alive entries are
        disjoint per (slot, epoch)). Until then, every read and every
        chained feed overlays the records inside its jitted program —
        no stream operation ever waits on the check (the suppressed R1
        host sync this replaces is retired)."""
        for p in list(self._pendings):
            if not p.alive.any():
                self._pendings.remove(p)
            elif p.fits.is_ready():
                self._finish_resolve(p)

    def poll(self) -> bool:
        """Public non-blocking maintenance poll: settle any pending
        record whose deferred ``fits`` vector the device has already
        delivered, releasing the record (and the full-capacity
        sub-state it keeps alive) eagerly instead of at the next
        stream op. Returns True while records remain — callers (the
        serve loop's idle tick) keep polling until the list drains."""
        self._maybe_resolve()
        return bool(self._pendings)

    def _force_resolve(self) -> None:
        """Blocking settle of every outstanding record — the sanctioned
        host sync, reached only from `drain`, never from a serving
        operation (feed chains records instead)."""
        while self._pendings:
            self._finish_resolve(self._pendings[0])

    def _finish_resolve(self, pend: _Pending) -> None:
        self._pendings.remove(pend)
        if not pend.alive.any():
            return
        fits = np.asarray(pend.fits)[pend.pos]
        bad = pend.alive & ~fits
        if bad.any():
            # some front outgrew its slot: splice the withheld states
            # into a rows bucket holding the largest such front (the
            # per-slot conditional scatter left those arena slots
            # untouched). Other records stay pending and keep being
            # overlaid — their entries are for different (slot, epoch)
            # pairs.
            counts = np.asarray(pend.sub[2])[pend.pos]
            self._promote(int(counts[bad].max()), pend)

    def drain(self) -> "SkylineStream":
        """Block until any deferred slot-overflow check from previous
        feeds has settled (promoting if a front outgrew its slot). The
        explicit, sanctioned synchronization point — tests and shutdown
        call it; the serving ops (`feed`/`tick`/`snapshot`) never do."""
        self._force_resolve()
        return self

    def _promote(self, need: int, pend: _Pending) -> None:
        """Move this stream's slots to the next rows bucket that holds
        ``need`` front rows, splicing the pending wave's inserted epoch
        states in at each tenant's recorded ring slot; the old slots go
        back to their arena's free list."""
        eng = self.engine
        new_rows = slot_rows_bucket(need, eng.min_slab_rows, self.cap)
        vals = _slab_promote_fn(self.rows, max(new_rows, self.rows),
                                self.q)(
            self.arena.leaves(), self._idx(), pend.epochs,
            tuple(pend.sub), pend.pos, pend.alive)
        if new_rows <= self.rows:
            # an earlier resolve already promoted past this record's
            # need (records settle independently): splice the withheld
            # states into the slots we already hold
            self.arena.set_leaves(_slab_put_fn(self.q, self.arena.donate)(
                self.arena.leaves(), self._idx(), vals))
            return
        new_arena = eng._arena(self.d, self.dtype, self.epochs, new_rows)
        new_slots = new_arena.lease(self.q)
        new_arena.set_leaves(_slab_put_fn(self.q, new_arena.donate)(
            new_arena.leaves(), np.asarray(new_slots, np.int32), vals))
        self.arena.release(self.slots)
        self.arena, self.slots, self.rows = new_arena, new_slots, new_rows

    def feed(self, chunks: Sequence[jnp.ndarray | None], *,
             masks: Sequence[jnp.ndarray | None] | None = None,
             ) -> "SkylineStream":
        """Absorb one arriving chunk per stream (``None`` / length-0 for
        streams with no new data) in a single insert dispatch (windowed
        streams: into each tenant's current head epoch). Never waits on
        the device: an unresolved overflow check from a previous wave is
        chained straight into this wave's jitted program."""
        items, mlist = self._feed_args(chunks, masks)
        _wave_feed(self.engine, [(self, items, mlist)])
        return self

    def _feed_args(self, chunks, masks) -> tuple[list, list]:
        """Validate one feed's per-stream chunk/mask lists (shared by
        the direct `feed` path and the serve loop's wave builder)."""
        if len(chunks) != self.q:
            raise ValueError(f"got {len(chunks)} chunks for {self.q} "
                             f"streams")
        if masks is None:
            masks = [None] * self.q
        elif len(masks) != self.q:
            raise ValueError(f"got {len(masks)} masks for {self.q} "
                             f"streams")
        items = [np.zeros((0, self.d), self.dtype) if c is None else c
                 for c in chunks]
        for c in items:
            if c.shape[1:] != (self.d,):
                raise ValueError(f"chunk shape {c.shape} does not match "
                                 f"stream d={self.d}")
        return items, list(masks)

    # -- epoch ring (windowed streams) -------------------------------------

    def tick(self, tenants: Sequence[int] | None = None) -> bool:
        """Open a new head epoch — for every tenant, or only the listed
        ones — in ONE dispatch; for a tenant with a full ring, the
        claimed slot held its oldest epoch and clearing it IS the expiry
        (O(1) — nothing recomputed). Each tenant has its own ring clock,
        so deadline-aware waves can age tenants at different rates.
        Returns whether any selected tenant expired an epoch."""
        if not self.windowed:
            raise ValueError("tick() needs a windowed stream "
                             "(StreamOptions(window_epochs=E))")
        self._maybe_resolve()
        sel = self._tenant_sel(tenants)
        new_head, new_active, expired = windowed.ring_advance(
            self._head, self._active, self.epochs)
        self.arena.set_leaves(_slab_clear_epoch_fn(self.arena.donate)(
            self.arena.leaves(), self._idx(),
            new_head.astype(np.int32), sel))
        for p in self._pendings:
            # pending entries whose ring slot was just cleared die with
            # it — the cleared epoch is authoritative now
            p.alive &= ~(sel & (p.epochs == new_head))
        self._head = np.where(sel, new_head, self._head).astype(np.int32)
        self._active = np.where(sel, new_active,
                                self._active).astype(np.int32)
        self.ticks += 1
        self.engine.batches_dispatched += 1
        return bool(np.any(expired & sel))

    def expire_epoch(self,
                     tenants: Sequence[int] | None = None,
                     ) -> "SkylineStream":
        """Drop the tail epoch of the selected tenants (default: all) in
        O(1) without opening a new one (expiring the only epoch empties
        it in place)."""
        if not self.windowed:
            raise ValueError("expire_epoch() needs a windowed stream")
        self._maybe_resolve()
        sel = self._tenant_sel(tenants)
        tail = windowed.ring_tail(self._head, self._active, self.epochs)
        self.arena.set_leaves(_slab_clear_epoch_fn(self.arena.donate)(
            self.arena.leaves(), self._idx(), tail.astype(np.int32),
            sel))
        for p in self._pendings:
            p.alive &= ~(sel & (p.epochs == tail))
        self._active = np.where(sel, np.maximum(self._active - 1, 1),
                                self._active).astype(np.int32)
        self.engine.batches_dispatched += 1
        return self

    # -- reads -------------------------------------------------------------

    def snapshot(self) -> list[SkyBuffer]:
        """Canonical `SkyBuffer` per live stream (non-destructive):
        windowed streams merge their epoch ring on read, unbounded ones
        canonicalize the packed antichain. An unresolved overflow record
        from a previous feed is overlaid INSIDE the jitted program — the
        read never host-blocks on the deferred fits vector."""
        self._maybe_resolve()
        pargs = self._pend_args()
        buf = _slab_snapshot_fn(self.engine.cfg, self.rows, self.epochs,
                                len(pargs) // 4)(
            self.arena.leaves(), self._idx(), *pargs)
        return list(_unpack_fn(self.q)(buf))

    def counters(self) -> dict[str, np.ndarray]:
        """Per-stream running stats (syncs its OWN scalars to host — an
        unresolved overflow record is overlaid in-program, like
        `snapshot`). For windowed streams ``count`` is the
        *retained-candidate* total (sum of per-epoch antichain sizes) —
        the window front size needs `snapshot` (cross-epoch dominance is
        resolved on read)."""
        self._maybe_resolve()
        pargs = self._pend_args()
        count, seen, chunks, overflow, per_epoch = _slab_counters_fn(
            len(pargs) // 4)(self.arena.leaves(), self._idx(), *pargs)
        # per-epoch front sizes into the engine histogram — counters()
        # is an off-hot-path host sync already (it is NOT in the R1
        # skylint HOT_PATHS), so the recording costs nothing extra
        self.engine.record_epoch_fronts(self.d, self.epochs,
                                        np.asarray(per_epoch))
        return {"count": np.asarray(count), "seen": np.asarray(seen),
                "chunks": np.asarray(chunks),
                "overflow": np.asarray(overflow)}

    def close(self) -> None:
        """Return the leased slots to the arena free list (any deferred
        fits check dies with the stream — nothing reads it again).

        A stream that was actually fed leaves its per-epoch front sizes
        in the engine's histogram on the way out (one final `counters`
        sync — close is not a hot path), so later `open_stream` calls
        can auto-size ``epoch_capacity`` from observed workloads."""
        if self.slots and self.chunks_fed:
            self.counters()
        self._pendings = []
        if self.slots:
            self.arena.release(self.slots)
            self.slots = []


# --------------------------------------------------------------------------
# Topology calibration: measure, don't guess, the vmap/sharded threshold
# --------------------------------------------------------------------------

def _candidate_factorings(engine: SkylineEngine,
                          d: int) -> list[tuple[int, int]]:
    """Every (queries x workers) factoring of the engine mesh's device
    count whose workers axis divides cfg's partition count at
    dimensionality ``d`` (the fused program's requirement)."""
    ndev = int(engine.mesh.devices.size)
    from repro.core.parallel import effective_parts
    p, _ = effective_parts(engine.cfg, d)
    return [(ndev // wa, wa) for wa in range(1, ndev + 1)
            if ndev % wa == 0 and p % wa == 0]


def calibrate_shard_threshold(engine: SkylineEngine, *,
                              bucket_sizes: Sequence[int] = (1024, 4096,
                                                            16384),
                              q: int | None = None, d: int = 4,
                              repeat: int = 3, apply: bool = True,
                              factorings: bool = True,
                              ) -> dict[str, Any]:
    """Measure vmap vs 2-D-sharded dispatch at a few N buckets on the
    live topology and set ``engine.shard_threshold_n`` — and, with
    ``factorings=True``, the per-bucket (queries x workers) mesh
    *factoring* — from data.

    For each bucket size a synthetic batch is packed once and timed
    through the compiled vmap pipeline and every candidate factoring of
    the mesh's device count (best-of-``repeat`` after a warmup that also
    pays compilation); the sharded time of a bucket is its best
    factoring's. The calibrated threshold is the smallest measured
    bucket from which the sharded program wins at every larger measured
    bucket as well (the threshold routes all larger buckets sharded); if
    no such bucket exists (typical on a single host where XLA:CPU
    already multithreads the vmapped batch), the threshold is
    effectively infinite so the engine stays on the vmap path at every
    size. Winning factorings land in ``engine.factorings`` (bucket ->
    (qa, wa, merge-mode)), which `SkylineEngine._mesh_for` /
    `_merge_mode_for` consult on dispatch — closing the last static
    mesh choice the throughput_sharded sweep showed matters (different
    factorings win at different N), and resolving ``cfg.merge ==
    'auto'`` per bucket: the winning factoring is additionally timed
    under the tree merge, and the faster topology becomes the bucket's
    merge-mode column. Returns a report dict (``threshold_n``,
    per-bucket timings incl. every factoring and both merge modes,
    chosen factorings as ``"QxW:mode"`` strings); with ``apply=False``
    the engine is left untouched.
    """
    if engine.mesh is None:
        return {"applied": False, "threshold_n": engine.shard_threshold_n,
                "measurements": {}, "factorings": {},
                "reason": "no mesh: vmap-only engine"}
    from repro.launch.mesh import make_engine_mesh
    # grid/angular derive their partition count from d, so a factoring
    # calibrated at one d can violate `p % workers == 0` at another —
    # per-bucket factorings are only stored for the d-independent
    # strategies; the threshold itself is still calibrated
    if engine.cfg.strategy not in ("sliced", "random"):
        factorings = False
    q = q or max(engine.mesh.shape[engine.q_axis], engine.min_q_bucket)
    cands = (_candidate_factorings(engine, d) if factorings
             else [tuple(engine.mesh.shape[a]
                         for a in (engine.q_axis, engine.w_axis))])
    meshes = {f: (engine.mesh
                  if f == tuple(engine.mesh.shape[a] for a in
                                (engine.q_axis, engine.w_axis))
                  else make_engine_mesh(f[0], f[1], q_axis=engine.q_axis,
                                        w_axis=engine.w_axis))
              for f in cands}
    measurements: dict[int, dict[str, Any]] = {}
    chosen: dict[int, tuple[int, int, str]] = {}
    for size in sorted(set(bucket_sizes)):
        nb = _next_bucket(size, engine.min_n_bucket)
        if nb in measurements:
            continue
        rng = np.random.default_rng(nb)
        queries = [jnp.asarray(rng.random((nb, d)), jnp.float32)
                   for _ in range(q)]

        def measure(fn, pts_b, mask_b, keys_b):
            jax.block_until_ready(fn(pts_b, mask_b, keys_b)[0].points)
            best = float("inf")
            for _ in range(repeat):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(pts_b, mask_b, keys_b)[0].points)
                best = min(best, time.perf_counter() - t0)
            return best

        qb = _next_bucket(q, engine.min_q_bucket)
        pts_b, mask_b = engine._pack(queries, [None] * q, range(q), qb)
        keys_b = jax.random.split(jax.random.PRNGKey(0), qb)
        timings: dict[str, float] = {
            "vmap": measure(fused_skyline_batch_fn(engine.cfg),
                            pts_b, mask_b, keys_b)}
        per_fact: dict[str, float] = {}
        for fact, mesh in meshes.items():
            qa, wa = fact
            qb_f = _round_up(_next_bucket(q, max(engine.min_q_bucket,
                                                 qa)), qa)
            pts_f, mask_f = engine._pack(queries, [None] * q, range(q),
                                         qb_f)
            keys_f = jax.random.split(jax.random.PRNGKey(0), qb_f)
            per_fact[f"{qa}x{wa}"] = measure(
                fused_skyline_batch_fn(engine.cfg, mesh, engine.q_axis,
                                       engine.w_axis),
                pts_f, mask_f, keys_f)
        best_name = min(per_fact, key=per_fact.get)
        qa, wa = (int(x) for x in best_name.split("x"))
        # merge-topology column: time the tree merge on the winning
        # factoring (the flat timing is that factoring's entry above)
        # so 'auto' configs route each bucket through the measured
        # winner instead of the modeled-bytes default
        cfg_tree = dataclasses.replace(engine.cfg, merge="tree")
        qb_f = _round_up(_next_bucket(q, max(engine.min_q_bucket, qa)),
                         qa)
        pts_f, mask_f = engine._pack(queries, [None] * q, range(q), qb_f)
        keys_f = jax.random.split(jax.random.PRNGKey(0), qb_f)
        tree_t = measure(
            fused_skyline_batch_fn(cfg_tree, meshes[(qa, wa)],
                                   engine.q_axis, engine.w_axis),
            pts_f, mask_f, keys_f)
        mode = "tree" if tree_t < per_fact[best_name] else "flat"
        chosen[nb] = (qa, wa, mode)
        timings["sharded"] = min(per_fact[best_name], tree_t)
        timings["factorings"] = per_fact
        timings["best_factoring"] = best_name
        timings["merge"] = {"flat": per_fact[best_name], "tree": tree_t}
        timings["best_merge"] = mode
        measurements[nb] = timings
    # the threshold routes EVERY bucket at or above it to the sharded
    # program, so pick the smallest measured bucket from which sharded
    # wins at every larger measured bucket too; when no such bucket
    # exists the engine must stay on the vmap path for *all* sizes, not
    # just the measured ones
    sizes = sorted(measurements)
    threshold = sys.maxsize
    for i, nb in enumerate(sizes):
        if all(measurements[m]["sharded"] < measurements[m]["vmap"]
               for m in sizes[i:]):
            threshold = nb
            break
    if apply:
        engine.shard_threshold_n = threshold
        if factorings:
            engine.factorings.update(chosen)
        for nb, t in measurements.items():
            engine.wave_time_hints[(d, "float32", nb)] = min(
                t["vmap"], t["sharded"])
    return {"applied": apply, "threshold_n": threshold,
            "measurements": measurements,
            "factorings": ({nb: f"{f[0]}x{f[1]}:{f[2]}"
                            for nb, f in chosen.items()}
                           if factorings else {})}
