"""Pareto-front request admission for the serving path (DESIGN.md §4).

Requests carry (deadline slack, -priority, estimated cost); the admission
batch is built skyline-first: no admitted request is dominated on all
three criteria by a rejected one.

Front computation goes through the batched `SkylineEngine`
(`repro.serve.engine`) so many queues — e.g. one per tenant or priority
class — are answered with a single vmapped dispatch (`admit_many`).
`admit` keeps the one-queue convenience signature and shares a default
module-level engine.

`StreamingAdmitter` is the arrival-time variant: requests trickle in, and
the admission front is *maintained* on device (`SkylineEngine.open_stream`
over the incremental `SkylineState`) instead of recomputed from the full
pool — each batch of arrivals is one insert dispatch for all queues.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.parallel import SkyConfig
from repro.serve.engine import SkylineEngine

__all__ = ["Request", "admit", "admit_many", "StreamingAdmitter",
           "default_engine", "make_default_engine"]


class Request(NamedTuple):
    slack: jnp.ndarray      # seconds to deadline (smaller = more urgent)
    neg_priority: jnp.ndarray
    cost: jnp.ndarray       # estimated decode tokens


_DEFAULT_ENGINE: SkylineEngine | None = None


def make_default_engine(cfg: SkyConfig = SkyConfig(),
                        **engine_kwargs) -> SkylineEngine:
    """Engine wired to the runtime: on a multi-device platform it gets a
    2-D (queries x workers) mesh — factored so the workers axis divides
    cfg's partition count — and large admission/query batches shard over
    it; on one device it is the plain vmap engine."""
    if "mesh" not in engine_kwargs and len(jax.devices()) > 1:
        from repro.launch.mesh import engine_mesh_shape, make_engine_mesh
        queries, workers = engine_mesh_shape(cfg.p)
        engine_kwargs["mesh"] = make_engine_mesh(queries, workers)
    return SkylineEngine(cfg, **engine_kwargs)


def default_engine() -> SkylineEngine:
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = make_default_engine()
    return _DEFAULT_ENGINE


def _criteria(reqs: Request) -> jnp.ndarray:
    crit = jnp.stack([reqs.slack, reqs.neg_priority, reqs.cost], axis=-1)
    lo = crit.min(0, keepdims=True)
    hi = crit.max(0, keepdims=True)
    return (crit - lo) / jnp.maximum(hi - lo, 1e-9)


def _rank(crit: jnp.ndarray, front: jnp.ndarray, batch_size: int):
    score = crit.sum(-1) + jnp.where(front, 0.0, 1e3)
    order = jnp.argsort(score)
    return order[:batch_size]


def admit(reqs: Request, batch_size: int, *,
          engine: SkylineEngine | None = None):
    """Pick up to batch_size requests, Pareto front first, then by an
    urgency score. Returns (indices, front_mask)."""
    crit = _criteria(reqs)
    front = (engine or default_engine()).member_masks([crit])[0]
    return _rank(crit, front, batch_size), front


def admit_many(queues: Sequence[Request], batch_size: int, *,
               engine: SkylineEngine | None = None):
    """Admission for Q independent queues in one engine dispatch.

    Returns a list of (indices, front_mask) pairs, one per queue."""
    crits = [_criteria(r) for r in queues]
    fronts = (engine or default_engine()).member_masks(crits)
    return [(_rank(c, f, batch_size), f) for c, f in zip(crits, fronts)]


def _raw_criteria(reqs: Request) -> jnp.ndarray:
    return jnp.stack([reqs.slack, reqs.neg_priority, reqs.cost], axis=-1)


class StreamingAdmitter:
    """Incrementally maintained admission fronts over arriving requests.

    Dominance is evaluated on the *raw* (slack, -priority, cost) criteria:
    the batch normalization `_criteria` applies is a per-dimension
    positive affine map, which never changes skyline membership, so the
    running front equals the front of the full request pool at every
    point in time — without retaining or re-scanning the pool. Ranking
    inside `admit` still normalizes, but within the (small) front only.
    """

    def __init__(self, *, queues: int = 1,
                 engine: SkylineEngine | None = None):
        self.engine = engine or default_engine()
        self.stream = self.engine.open_stream(3, q=queues)
        self.queues = queues

    def offer(self, arrivals: Sequence[Request | None]) -> None:
        """Absorb one batch of arrivals per queue (None = no arrivals)
        with a single insert dispatch across all queues."""
        if len(arrivals) != self.queues:
            raise ValueError(f"got {len(arrivals)} arrival batches for "
                             f"{self.queues} queues")
        self.stream.feed([None if r is None else _raw_criteria(r)
                          for r in arrivals])

    def fronts(self) -> list[np.ndarray]:
        """Current Pareto-front criteria rows, one (F_i, 3) per queue."""
        return [np.asarray(buf.points)[np.asarray(buf.mask)]
                for buf in self.stream.snapshot()]

    def admit(self, batch_size: int) -> list[np.ndarray]:
        """Up to batch_size front criteria rows per queue, most urgent
        (normalized criteria sum) first. Returns raw criteria rows — a
        streaming pool has no stable request indices to hand back."""
        out = []
        for front in self.fronts():
            if front.shape[0] == 0:
                out.append(front)
                continue
            lo, hi = front.min(0, keepdims=True), front.max(0, keepdims=True)
            score = ((front - lo) / np.maximum(hi - lo, 1e-9)).sum(-1)
            out.append(front[np.argsort(score)][:batch_size])
        return out
