"""Pareto-front request admission for the serving path (DESIGN.md §4).

Requests carry (deadline slack, -priority, estimated cost); the admission
batch is built skyline-first: no admitted request is dominated on all
three criteria by a rejected one.

Front computation goes through the batched `SkylineEngine`
(`repro.serve.engine`) so many queues — e.g. one per tenant or priority
class — are answered with a single vmapped dispatch (`admit_many`).
`admit` keeps the one-queue convenience signature and shares a default
module-level engine.

`StreamingAdmitter` is the arrival-time variant: requests trickle in, and
the admission front is *maintained* on device (`SkylineEngine.open_stream`
over the incremental `SkylineState`) instead of recomputed from the full
pool — each batch of arrivals is one insert dispatch for all queues.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.parallel import SkyConfig
from repro.serve.engine import SkylineEngine, StreamOptions

__all__ = ["Request", "admit", "admit_many", "StreamingAdmitter",
           "WindowedAdmitter", "default_engine", "make_default_engine"]


class Request(NamedTuple):
    slack: jnp.ndarray      # seconds to deadline (smaller = more urgent)
    neg_priority: jnp.ndarray
    cost: jnp.ndarray       # estimated decode tokens


_DEFAULT_ENGINE: SkylineEngine | None = None


def make_default_engine(cfg: SkyConfig = SkyConfig(),
                        **engine_kwargs) -> SkylineEngine:
    """Engine wired to the runtime: on a multi-device platform it gets a
    2-D (queries x workers) mesh — factored so the workers axis divides
    cfg's partition count — and large admission/query batches shard over
    it; on one device it is the plain vmap engine."""
    if "mesh" not in engine_kwargs and len(jax.devices()) > 1:
        from repro.launch.mesh import engine_mesh_shape, make_engine_mesh
        queries, workers = engine_mesh_shape(cfg.p)
        engine_kwargs["mesh"] = make_engine_mesh(queries, workers)
    return SkylineEngine(cfg, **engine_kwargs)


def default_engine() -> SkylineEngine:
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = make_default_engine()
    return _DEFAULT_ENGINE


def _criteria(reqs: Request) -> jnp.ndarray:
    crit = jnp.stack([reqs.slack, reqs.neg_priority, reqs.cost], axis=-1)
    lo = crit.min(0, keepdims=True)
    hi = crit.max(0, keepdims=True)
    return (crit - lo) / jnp.maximum(hi - lo, 1e-9)


def _rank(crit: jnp.ndarray, front: jnp.ndarray, batch_size: int):
    score = crit.sum(-1) + jnp.where(front, 0.0, 1e3)
    order = jnp.argsort(score)
    return order[:batch_size]


def admit(reqs: Request, batch_size: int, *,
          engine: SkylineEngine | None = None):
    """Pick up to batch_size requests, Pareto front first, then by an
    urgency score. Returns (indices, front_mask)."""
    crit = _criteria(reqs)
    front = (engine or default_engine()).member_masks([crit])[0]
    return _rank(crit, front, batch_size), front


def admit_many(queues: Sequence[Request], batch_size: int, *,
               engine: SkylineEngine | None = None):
    """Admission for Q independent queues in one engine dispatch.

    Returns a list of (indices, front_mask) pairs, one per queue."""
    crits = [_criteria(r) for r in queues]
    fronts = (engine or default_engine()).member_masks(crits)
    return [(_rank(c, f, batch_size), f) for c, f in zip(crits, fronts)]


def _raw_criteria(reqs: Request) -> jnp.ndarray:
    return jnp.stack([reqs.slack, reqs.neg_priority, reqs.cost], axis=-1)


def _rank_rows(rows: np.ndarray, k: int) -> np.ndarray:
    """Up to k criteria rows, most urgent (normalized sum) first."""
    if rows.shape[0] == 0:
        return rows
    lo, hi = rows.min(0, keepdims=True), rows.max(0, keepdims=True)
    score = ((rows - lo) / np.maximum(hi - lo, 1e-9)).sum(-1)
    return rows[np.argsort(score)][:k]


def _snapshot_fronts(stream) -> list[np.ndarray]:
    return [np.asarray(buf.points)[np.asarray(buf.mask)]
            for buf in stream.snapshot()]


class StreamingAdmitter:
    """Incrementally maintained admission fronts over arriving requests.

    Dominance is evaluated on the *raw* (slack, -priority, cost) criteria:
    the batch normalization `_criteria` applies is a per-dimension
    positive affine map, which never changes skyline membership, so the
    running front equals the front of the full request pool at every
    point in time — without retaining or re-scanning the pool. Ranking
    inside `admit` still normalizes, but within the (small) front only.

    With ``backfill=True`` a *second layer* is maintained too: the
    skyline of the non-front pool, so `admit` can fill a batch when the
    first-layer front is smaller than ``batch_size``. The second layer
    is exact by construction: a request leaves the first layer exactly
    once — rejected on arrival or evicted later (the pool is
    insert-only, so demotion is permanent) — and is fed to a shadow
    stream at that moment, making the shadow pool identically
    ``pool minus front`` and its running front SKY(pool \\ front).
    Detecting demotions means reading the front back after each offer
    (one small device sync per wave), which is why backfill is opt-in.
    """

    def __init__(self, *, queues: int = 1,
                 engine: SkylineEngine | None = None,
                 backfill: bool = False):
        self.engine = engine or default_engine()
        self.stream = self.engine.open_stream(3, StreamOptions(q=queues))
        self.queues = queues
        self.backfill = backfill
        if backfill:
            self.shadow = self.engine.open_stream(
                3, StreamOptions(q=queues))
            self._fronts = [np.zeros((0, 3), np.float32)
                            for _ in range(queues)]

    def offer(self, arrivals: Sequence[Request | None]) -> None:
        """Absorb one batch of arrivals per queue (None = no arrivals)
        with a single insert dispatch across all queues."""
        if len(arrivals) != self.queues:
            raise ValueError(f"got {len(arrivals)} arrival batches for "
                             f"{self.queues} queues")
        batches = [None if r is None else _raw_criteria(r)
                   for r in arrivals]
        self.stream.feed(batches)
        if not self.backfill:
            return
        # demotions this wave: arrival rows that did not reach the new
        # front, plus old front rows evicted from it (value-equality is
        # the membership test — a duplicate of a front member joins the
        # front itself, so it is never demoted)
        new_fronts = self.fronts()
        demoted: list[jnp.ndarray | None] = []
        for qi in range(self.queues):
            fset = {r.tobytes()
                    for r in np.ascontiguousarray(new_fronts[qi])}
            rows = [r for r in self._fronts[qi]
                    if r.tobytes() not in fset]
            if batches[qi] is not None:
                rows += [r for r in np.ascontiguousarray(
                    np.asarray(batches[qi], np.float32))
                    if r.tobytes() not in fset]
            demoted.append(jnp.asarray(np.asarray(rows, np.float32)
                                       .reshape(-1, 3))
                           if rows else None)
        self._fronts = [np.ascontiguousarray(f) for f in new_fronts]
        if any(d is not None for d in demoted):
            self.shadow.feed(demoted)

    def fronts(self) -> list[np.ndarray]:
        """Current Pareto-front criteria rows, one (F_i, 3) per queue."""
        return _snapshot_fronts(self.stream)

    def second_layer_fronts(self) -> list[np.ndarray]:
        """SKY(pool \\ front) per queue (requires ``backfill=True``)."""
        if not self.backfill:
            raise ValueError("second layer needs backfill=True")
        return _snapshot_fronts(self.shadow)

    def admit(self, batch_size: int) -> list[np.ndarray]:
        """Up to batch_size front criteria rows per queue, most urgent
        (normalized criteria sum) first; with ``backfill=True``, batches
        short of ``batch_size`` are topped up from the second layer.
        Returns raw criteria rows — a streaming pool has no stable
        request indices to hand back."""
        out = []
        seconds = (self.second_layer_fronts() if self.backfill
                   else [None] * self.queues)
        # with backfill on, offer() just snapshotted the primary fronts
        # (to detect demotions) — reuse that host-side cache instead of
        # paying a second merge-on-read dispatch here
        fronts = self._fronts if self.backfill else self.fronts()
        for front, layer2 in zip(fronts, seconds):
            picked = _rank_rows(front, batch_size)
            if layer2 is not None and picked.shape[0] < batch_size:
                fill = _rank_rows(layer2, batch_size - picked.shape[0])
                picked = np.concatenate([picked, fill]) if fill.size \
                    else picked
            out.append(picked)
        return out


class WindowedAdmitter:
    """Admission fronts that *age out*: requests count toward the front
    only for the last ``window_epochs`` ticks.

    The fronts live in a windowed stream (`SkylineEngine.open_stream`
    with ``window_epochs`` — an epoch ring per queue,
    repro.core.windowed): `offer` feeds the current head epoch, `tick`
    rotates the ring (one O(1) dispatch across all queues; a full ring
    expires its oldest epoch), and `fronts`/`admit` read the
    merge-on-read snapshot — always exactly the Pareto front of the
    requests offered in the live window, including members that were
    cross-epoch dominated when they arrived and were un-dominated by an
    expiry since (retained candidates make aging exact)."""

    def __init__(self, *, queues: int = 1, window_epochs: int = 4,
                 engine: SkylineEngine | None = None):
        self.engine = engine or default_engine()
        self.stream = self.engine.open_stream(
            3, StreamOptions(q=queues, window_epochs=window_epochs))
        self.queues = queues
        self.window_epochs = window_epochs

    def offer(self, arrivals: Sequence[Request | None]) -> None:
        """Absorb one batch of arrivals per queue into the head epoch
        (one insert dispatch across all queues)."""
        if len(arrivals) != self.queues:
            raise ValueError(f"got {len(arrivals)} arrival batches for "
                             f"{self.queues} queues")
        self.stream.feed([None if r is None else _raw_criteria(r)
                          for r in arrivals])

    def tick(self) -> bool:
        """Advance the window clock for every queue in one dispatch;
        returns whether an epoch of requests aged out."""
        return self.stream.tick()

    def fronts(self) -> list[np.ndarray]:
        """Pareto front of the live window per queue, one (F_i, 3)."""
        return _snapshot_fronts(self.stream)

    def admit(self, batch_size: int) -> list[np.ndarray]:
        """Up to batch_size live-window front rows per queue, most
        urgent first."""
        return [_rank_rows(front, batch_size) for front in self.fronts()]
