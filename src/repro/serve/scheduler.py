"""Pareto-front request admission for the serving path (DESIGN.md §4).

Requests carry (deadline slack, -priority, estimated cost); the admission
batch is built skyline-first: no admitted request is dominated on all
three criteria by a rejected one.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import skyline_mask

__all__ = ["Request", "admit"]


class Request(NamedTuple):
    slack: jnp.ndarray      # seconds to deadline (smaller = more urgent)
    neg_priority: jnp.ndarray
    cost: jnp.ndarray       # estimated decode tokens


def admit(reqs: Request, batch_size: int):
    """Pick up to batch_size requests, Pareto front first, then by an
    urgency score. Returns (indices, front_mask)."""
    crit = jnp.stack([reqs.slack, reqs.neg_priority, reqs.cost], axis=-1)
    lo = crit.min(0, keepdims=True)
    hi = crit.max(0, keepdims=True)
    crit = (crit - lo) / jnp.maximum(hi - lo, 1e-9)
    front = skyline_mask(crit)
    score = crit.sum(-1) + jnp.where(front, 0.0, 1e3)
    order = jnp.argsort(score)
    return order[:batch_size], front
