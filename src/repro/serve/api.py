"""Request-oriented serving API: `SkylineRequest` + `StreamOptions`.

The engine's entry-point surface grew one method per query family
(``run`` for ragged batches, ``run_scaled`` for preference views,
``run_subspace`` for subspace views) plus a widening ``open_stream``
knob list. This module consolidates both into two validated config
objects:

  ``SkylineRequest``  — ONE skyline query: its data, an optional user
                        mask, an optional preference-scale or subspace
                        *view* of the data, an optional partitioning
                        key, an optional latency deadline, an optional
                        kernel-backend override. `SkylineEngine.submit`
                        / ``submit_many`` answer any mix of requests in
                        bucketed single-dispatch waves; the async serve
                        loop (`repro.serve.loop`) dispatches the same
                        objects with deadlines enforced by its wave
                        scheduler.
  ``StreamOptions``   — every `open_stream` knob, keyword-only, checked
                        at construction — so the stream surface stays
                        two parameters (``d``, ``options``) no matter
                        how many knobs future query families add.

Both are frozen: a request/options object can be reused, logged, and
hashed-by-identity across waves without defensive copies.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import resolve_spec

__all__ = ["SkylineRequest", "StreamOptions"]


@dataclasses.dataclass(frozen=True, eq=False)
class SkylineRequest:
    """One skyline query for `SkylineEngine.submit` / the serve loop.

    Exactly one of the two *view* fields may be set: ``scale`` is a
    ``(d,)`` vector of positive per-attribute preference scales (the
    query answers the skyline of ``data * scale``), ``subspace`` is a
    ``(d,)`` bool mask selecting the attributes that discriminate.
    Requests sharing the same ``data`` object and view kind are stacked
    into one broadcast dispatch (the old ``run_scaled``/``run_subspace``
    fast path); plain requests group by (d, dtype, N-bucket).

    ``deadline`` is an absolute `time.monotonic()` instant. The
    synchronous ``submit`` path ignores it (the caller is already
    waiting); the async serve loop's admission control sheds or degrades
    requests that cannot meet it.

    ``impl`` overrides the engine's kernel backend for this request
    only (resolved — and therefore validated — at construction).
    """

    data: Any
    mask: Any | None = None
    scale: Any | None = None
    subspace: Any | None = None
    key: Any | None = None
    deadline: float | None = None
    impl: str | None = None

    def __post_init__(self):
        if getattr(self.data, "ndim", None) != 2:
            raise ValueError("request data must be a (N, d) array")
        if self.scale is not None and self.subspace is not None:
            raise ValueError("scale and subspace are mutually exclusive "
                             "views of the data")
        d = self.data.shape[1]
        for name in ("scale", "subspace"):
            v = getattr(self, name)
            if v is not None and tuple(np.shape(v)) != (d,):
                raise ValueError(f"{name} must be shape ({d},) to match "
                                 f"data with d={d}, got {np.shape(v)}")
        if self.impl is not None:
            resolve_spec(self.impl)  # unknown backends fail fast here

    @property
    def view_kind(self) -> str | None:
        """"scale" / "subspace" for view requests, None for plain."""
        if self.scale is not None:
            return "scale"
        if self.subspace is not None:
            return "subspace"
        return None


@dataclasses.dataclass(frozen=True, eq=False)
class StreamOptions:
    """Every `open_stream` knob, validated at construction.

    ``q`` live skylines share the stream's slab slots and dispatch
    waves; ``window_epochs=E`` makes them sliding windows over an
    E-slot epoch ring, and ``epoch_capacity`` bounds each epoch's
    retained-candidate buffer (see `repro.core.windowed.epoch_rows`).
    ``key`` seeds the partitioning of fed chunks (any deterministic
    stream is valid — the key never changes results, only partition
    assignment).
    """

    q: int = 1
    dtype: Any = jnp.float32
    key: Any | None = None
    window_epochs: int | None = None
    epoch_capacity: int = 0

    def __post_init__(self):
        if self.q < 1:
            raise ValueError(f"need at least one stream, got q={self.q}")
        if self.window_epochs is not None and self.window_epochs < 1:
            raise ValueError(f"window_epochs must be >= 1, got "
                             f"{self.window_epochs}")
        if self.epoch_capacity and self.window_epochs is None:
            raise ValueError("epoch_capacity needs a windowed stream "
                             "(StreamOptions(window_epochs=E)); an "
                             "unbounded stream's slots are bounded by "
                             "the state capacity already")
        if self.epoch_capacity < 0:
            raise ValueError(f"epoch_capacity must be >= 0, got "
                             f"{self.epoch_capacity}")
