"""Shared slab allocator for thousands of tenant stream states.

Before this module, every stream group allocated its own device buffers
(Q x C skyline rows per `SkylineState`), so a fleet of small tenants
paid O(#streams) device allocations of C rows each even while idle. The
slab allocator inverts that: ONE device-resident arena per *bucket key*
(d, dtype, epochs, slot rows) holds all tenant states as leased slots,
so device buffers scale with the number of buckets, never the number of
streams — and a tenant's resident footprint is its slot's row count
(a small power-of-two that tracks its *front* size), not the engine's
full C-row state capacity.

  ``SlabArena``  — the arena: one array per state leaf with a leading
                   slot axis ((S, E, R, d) points, (S, E, R) mask,
                   (S, E) int/bool stats), a host-side free list, and
                   doubling growth (growth replaces the old leaves, so
                   the live buffer count stays O(1) per arena).
  ``lease(k)``   — claim k slots (grown + re-blanked as needed).
  ``release``    — return slots to the free list (cleared lazily at the
                   next lease, one batched dispatch).

Streams gather their slots into a batched state, run the ordinary
(windowed) insert, and scatter the packed fronts back — the engine
fuses gather + insert + scatter into one jitted program per bucket
(`repro.serve.engine`). When a front outgrows its slot, the stream is
*promoted* to the next power-of-two rows bucket (a different arena);
truncation never happens silently.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dominance import SENTINEL

__all__ = ["SlabArena", "slot_rows_bucket", "blank_leaf"]


def blank_leaf(shape, dtype) -> jnp.ndarray:
    """The empty-slot value of one state leaf: sentinel-filled for point
    coordinates (the repo-wide invalid-row convention,
    repro.core.dominance), zeros for masks and stats. The single
    definition shared by arena blanking and the engine's epoch-clear
    program."""
    dtype = jnp.dtype(dtype)
    if dtype.kind == "f":
        return jnp.full(shape, SENTINEL, dtype)
    return jnp.zeros(shape, dtype)


def slot_rows_bucket(rows_needed: int, floor: int, cap: int) -> int:
    """Smallest power-of-two slot row count >= rows_needed, floored at
    ``floor`` and clipped to ``cap`` (the full state capacity — at the
    cap a slot holds the complete state and can never overflow)."""
    b = max(int(floor), 1)
    while b < rows_needed and b < cap:
        b *= 2
    return min(b, cap)


@functools.lru_cache(maxsize=None)
def _blank_fn(donate: bool = True):
    """One jitted dispatch blanking a batch of slots in every leaf.
    ``donate`` is a cache key (no cfg reaches this factory): by default
    the stale leaves are reused in place — the caller (`lease`) is the
    arena itself, the single owner, and rebinds immediately."""

    def run(leaves, idx):
        return tuple(a.at[idx].set(blank_leaf(a.shape[1:], a.dtype))
                     for a in leaves)

    return jax.jit(run, donate_argnums=(0,)) if donate else jax.jit(run)


class SlabArena:
    """Device-resident slot arena for one bucket key.

    The six leaves mirror the windowed state's epoch leaves with a
    leading slot axis; slot contents are always a *packed* state (valid
    rows first), so an R-row slot faithfully round-trips any state
    whose per-epoch fronts fit in R rows.
    """

    def __init__(self, *, epochs: int, rows: int, d: int,
                 dtype=jnp.float32, init_slots: int = 8,
                 donate: bool = True):
        self.epochs = int(epochs)
        self.rows = int(rows)
        self.d = int(d)
        self.dtype = jnp.dtype(dtype)
        # single-owner protocol: with donate on, every update program fed
        # from `leaves()` consumes the buffers and `set_leaves` installs
        # the aliased outputs — no other live reference may survive the
        # dispatch (overlays copy out the O(front) rows they need first)
        self.donate = bool(donate)
        s = max(int(init_slots), 1)
        self._leaves = self._alloc(s)
        self._free: list[int] = list(range(s))[::-1]
        self._free_set: set[int] = set(self._free)
        self._dirty: set[int] = set()
        self.leased = 0
        self.grows = 0

    # -- storage -----------------------------------------------------------

    def _alloc(self, slots: int):
        e, r, d = self.epochs, self.rows, self.d
        return (
            jnp.full((slots, e, r, d), SENTINEL, self.dtype),  # points
            jnp.zeros((slots, e, r), jnp.bool_),               # mask
            jnp.zeros((slots, e), jnp.int32),                  # count
            jnp.zeros((slots, e), jnp.bool_),                  # overflow
            jnp.zeros((slots, e), jnp.int32),                  # seen
            jnp.zeros((slots, e), jnp.int32),                  # chunks
        )

    @property
    def capacity(self) -> int:
        return self._leaves[0].shape[0]

    @property
    def free(self) -> int:
        """Slots available without growing — the serve loop's wave
        coalescer reads this to group same-bucket tenants: every stream
        leasing from ONE arena is wave-fusable with every other (their
        slots gather/scatter through the same leaves in one dispatch)."""
        return self.capacity - self.leased

    def leaves(self):
        """The current arena leaves (points, mask, count, overflow,
        seen, chunks) — pass to a jitted gather/scatter program and
        store the returned updates with `set_leaves`."""
        return self._leaves

    def set_leaves(self, leaves) -> None:
        if len(leaves) != len(self._leaves):
            raise ValueError("leaf arity mismatch")
        self._leaves = tuple(leaves)

    # -- accounting (the O(#buckets) assertion reads these) ----------------

    def num_buffers(self) -> int:
        """Device arrays held by this arena — constant per arena."""
        return len(self._leaves)

    def device_bytes(self) -> int:
        return sum(a.size * a.dtype.itemsize for a in self._leaves)

    # -- slot lifecycle ----------------------------------------------------

    def _grow(self, need: int) -> None:
        old = self.capacity
        new = old
        while new < old + need:
            new *= 2
        extra = self._alloc(new - old)
        self._leaves = tuple(
            jnp.concatenate([a, b]) for a, b in zip(self._leaves, extra))
        self._free.extend(range(old, new)[::-1])
        self._free_set.update(range(old, new))
        self.grows += 1

    def lease(self, k: int) -> list[int]:
        """Claim k blank slots (grows the arena by doubling if the free
        list runs short; previously-released slots are re-blanked in one
        batched dispatch)."""
        if k < 1:
            raise ValueError(f"lease needs k >= 1, got {k}")
        if len(self._free) < k:
            self._grow(k - len(self._free))
        slots = [self._free.pop() for _ in range(k)]
        self._free_set.difference_update(slots)
        stale = [s for s in slots if s in self._dirty]
        if stale:
            self._leaves = _blank_fn(self.donate)(
                self._leaves, jnp.asarray(stale, jnp.int32))
            self._dirty.difference_update(stale)
        self.leased += k
        return slots

    def release(self, slots) -> None:
        """Return slots to the free list; contents are cleared lazily at
        the next lease that reuses them. Double-releasing (or releasing
        a slot this arena never allocated) raises — a stale slot list
        would otherwise let two tenants lease the same slot and
        silently overwrite each other's state."""
        slots = [int(s) for s in slots]
        bad = [s for s in slots
               if s in self._free_set or not 0 <= s < self.capacity]
        if bad:
            raise ValueError(f"slots {bad} are not currently leased "
                             f"from this arena")
        for s in slots:
            self._dirty.add(s)
            self._free.append(s)
        self._free_set.update(slots)
        self.leased -= len(slots)
