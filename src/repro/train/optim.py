"""AdamW with cosine schedule, global-norm clipping, optional low-precision
moments (large-MoE memory budget, DESIGN.md §6), and optional int8 gradient
compression with error feedback.

The compression models the data-axis all-reduce payload reduction: in a
shard_map deployment the quantized tensor is what crosses the ICI links.
Error feedback keeps the quantization noise from biasing the trajectory.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "cosine_lr",
           "clip_by_global_norm", "quantize_int8", "dequantize_int8"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # bfloat16 for llama4-scale
    compress: str | None = None      # None | "int8"


def cosine_lr(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def adamw_init(params, cfg: OptConfig):
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state: dict[str, Any] = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress == "int8":
        state["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                    params)
    return state


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_lr(step, cfg)
    metrics = {}

    if cfg.compress == "int8":
        # error-feedback compression of the (to-be-all-reduced) gradient
        def comp(g, e):
            gq, scale = quantize_int8(g.astype(jnp.float32)
                                      + e.astype(jnp.float32))
            gd = dequantize_int8(gq, scale, jnp.float32)
            return gd.astype(g.dtype), (g.astype(jnp.float32)
                                        + e.astype(jnp.float32) - gd
                                        ).astype(g.dtype)
        pairs = jax.tree.map(comp, grads, opt_state["err"])
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))

    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    metrics["grad_norm"] = gnorm
    metrics["lr"] = lr

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = vf / (1 - cfg.b2 ** step.astype(jnp.float32))
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    unzip = lambda i: jax.tree.map(lambda t: t[i], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_params = unzip(0)
    new_state = {"m": unzip(1), "v": unzip(2), "step": step}
    if cfg.compress == "int8":
        new_state["err"] = new_err
    return new_params, new_state, metrics
