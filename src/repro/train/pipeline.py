"""GPipe-style pipeline parallelism over a `stage` mesh axis.

Each device (stage) holds one segment of the layer stack; microbatches
stream through a (n_micro + n_stages - 1)-tick schedule with
`lax.ppermute` passing activations to the next stage. The bubble fraction
is the standard (S-1)/(M+S-1).

This is the optional PP dimension of the parallelism suite (DESIGN.md §6)
— exercised at small scale in tests (tests/test_pipeline.py) and usable
under `jax.shard_map` with a ("stage",) mesh; the main production configs
use DP x TP (+EP/SP), where PP is unnecessary at 256-512 chips.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

__all__ = ["gpipe_forward", "pipeline_stages"]


def pipeline_stages(params_stacked, n_stages: int):
    """Split a (L, ...)-stacked layer pytree into (n_stages, L/S, ...)."""
    def f(x):
        n = x.shape[0]
        assert n % n_stages == 0, (n, n_stages)
        return x.reshape(n_stages, n // n_stages, *x.shape[1:])
    return jax.tree.map(f, params_stacked)


def gpipe_forward(stage_fn, params_local, micro_inputs, *,
                  axis: str = "stage"):
    """Run inside shard_map over `axis`.

    Args:
      stage_fn: (stage_params, x) -> y, one pipeline stage.
      params_local: this stage's parameters (leading (1, ...) shard of the
        (n_stages, ...) stacked tree).
      micro_inputs: (n_micro, B, ...) microbatched inputs (replicated
        across stages; only stage 0 reads them).

    Returns:
      (n_micro, B, ...) outputs (valid on the last stage; callers psum or
      gather as needed).
    """
    n_stages = jax.lax.psum(1, axis)
    sidx = jax.lax.axis_index(axis)
    n_micro = micro_inputs.shape[0]
    params_local = jax.tree.map(lambda p: p[0], params_local)

    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(carry, t):
        recv, outs = carry
        mb = jnp.clip(t, 0, n_micro - 1)
        x0 = micro_inputs[mb]
        x_in = jnp.where(sidx == 0, x0, recv)
        y = stage_fn(params_local, x_in)
        # emit on the last stage when microbatch t-(S-1) completes
        out_idx = t - (n_stages - 1)
        valid = (sidx == n_stages - 1) & (out_idx >= 0)
        outs = jax.lax.cond(
            valid,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, jnp.maximum(out_idx, 0), 0),
            lambda o: o, outs)
        recv = jax.lax.ppermute(y, axis, perm)
        return (recv, outs), None

    recv0 = jnp.zeros_like(micro_inputs[0])
    outs0 = jnp.zeros_like(micro_inputs)
    (recv, outs), _ = jax.lax.scan(
        tick, (recv0, outs0), jnp.arange(ticks))
    # outs is nonzero only on the last stage: psum broadcasts it
    return jax.lax.psum(outs, axis)
