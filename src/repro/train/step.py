"""Train step: microbatched gradient accumulation (lax.scan), remat'd
blocks, mixed precision, AdamW — the function the dry-run lowers.

TrainState = {"params", "opt": {m, v, step[, err]}, "step"}.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import Sharder
from repro.train.optim import OptConfig, adamw_init, adamw_update

__all__ = ["init_state", "make_train_step", "make_eval_step"]


def init_state(params, opt_cfg: OptConfig):
    return {"params": params, "opt": adamw_init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def _split_micro(batch, k: int, sharder=None):
    """(B, ...) -> (k, B//k, ...) for scan-based accumulation. The reshape
    crosses the sharded batch dim, so re-constrain the result (otherwise
    GSPMD falls back to involuntary replication on the multi-pod mesh)."""
    def f(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        y = x.reshape(k, b // k, *x.shape[1:])
        if sharder is not None:
            y = sharder(y, None, "batch", *([None] * (y.ndim - 2)))
        return y
    return jax.tree.map(f, batch)


def make_train_step(cfg, opt_cfg: OptConfig, *, rules=None,
                    shard_activations: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""
    sharder = Sharder(rules, enabled=shard_activations)
    k = max(cfg.microbatches, 1)

    def loss_for_grads(params, mb):
        loss, metrics = T.loss_fn(params, cfg, mb, sharder=sharder)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grads, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        micro = _split_micro(batch, k,
                             sharder if shard_activations else None)

        def accum(carry, mb):
            gacc, lacc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            gacc = jax.tree.map(jnp.add, gacc, grads)
            return (gacc, lacc + loss), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        (gsum, lsum), ms = jax.lax.scan(accum, (g0, jnp.float32(0.0)),
                                        micro,
                                        unroll=True if cfg.scan_unroll
                                        else 1)
        grads = jax.tree.map(lambda g: g / k, gsum)
        new_params, new_opt, om = adamw_update(grads, state["opt"], params,
                                               opt_cfg)
        metrics = {key: jnp.mean(val) for key, val in ms.items()}
        metrics.update(om)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_eval_step(cfg, *, rules=None, shard_activations: bool = False):
    sharder = Sharder(rules, enabled=shard_activations)

    def eval_step(params, batch):
        loss, metrics = T.loss_fn(params, cfg, batch, sharder=sharder)
        return metrics

    return eval_step
