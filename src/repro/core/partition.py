"""Partitioning strategies (paper §3): RANDOM, GRID, ANGULAR, SLICED.

Each strategy maps every tuple to a partition id in [0, p). The SPMD
runtime then routes tuples into fixed-capacity per-partition buckets
(`bucketize`) — the static-shape analogue of Spark's shuffle
(DESIGN.md §3 change (2)).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dominance import SENTINEL

__all__ = [
    "random_part_ids", "grid_part_ids", "grid_cell_coords",
    "angular_part_ids", "sliced_part_ids", "bucketize", "Buckets",
    "grid_num_parts", "angular_num_parts", "slices_for_target_parts",
]


class Buckets(NamedTuple):
    points: jnp.ndarray    # (p, C, d)
    mask: jnp.ndarray      # (p, C) bool
    counts: jnp.ndarray    # (p,) int32 true per-partition populations
    overflow: jnp.ndarray  # () bool — some partition exceeded capacity


# --------------------------------------------------------------------------
# Partition-id maps
# --------------------------------------------------------------------------

def random_part_ids(key: jax.Array, n: int, p: int) -> jnp.ndarray:
    """Balanced random assignment: a random permutation of residues mod p
    (exactly equi-numerous when p | n, off by one otherwise) — paper §3.1."""
    return jax.random.permutation(key, jnp.arange(n, dtype=jnp.int32) % p)


def grid_cell_coords(pts: jnp.ndarray, m: int) -> jnp.ndarray:
    """(N, d) int32 grid coordinates on [0,1]^d with m slices per dim."""
    return jnp.clip(jnp.floor(pts * m), 0, m - 1).astype(jnp.int32)


def grid_part_ids(pts: jnp.ndarray, m: int) -> jnp.ndarray:
    """p(t) = sum_i floor(t[A_i] * m) * m^(i-1) — paper §3.2."""
    d = pts.shape[1]
    coords = grid_cell_coords(pts, m)
    radix = (m ** jnp.arange(d, dtype=jnp.int32))
    return jnp.sum(coords * radix[None, :], axis=1)


def angular_part_ids(pts: jnp.ndarray, m: int) -> jnp.ndarray:
    """Hyperspherical partitioning (paper §3.3, Eq. 1): grid on the d-1
    angular coordinates; phi_i = arctan(sqrt(sum_{j>i} x_j^2) / x_i)."""
    n, d = pts.shape
    if d < 2:
        return jnp.zeros((n,), jnp.int32)
    x2 = pts.astype(jnp.float32) ** 2
    # tail[i] = sum_{j > i} x_j^2 via reversed cumulative sum
    rev_cum = jnp.cumsum(x2[:, ::-1], axis=1)[:, ::-1]
    tail = jnp.concatenate(
        [rev_cum[:, 1:], jnp.zeros((n, 1), jnp.float32)], axis=1)
    phi = jnp.arctan2(jnp.sqrt(tail[:, :d - 1]), pts[:, :d - 1])  # [0, pi/2]
    slot = jnp.clip(jnp.floor(2.0 * phi / jnp.pi * m), 0, m - 1)
    radix = (m ** jnp.arange(d - 1, dtype=jnp.int32))
    return jnp.sum(slot.astype(jnp.int32) * radix[None, :], axis=1)


def sliced_part_ids(pts: jnp.ndarray, mask: jnp.ndarray, p: int,
                    dim: int = 0) -> jnp.ndarray:
    """SLICED (paper §3.4): sort on one dimension (index tie-break -> total
    order), cut into p equal runs: p(t) = floor(rank * p / N_valid)."""
    n = pts.shape[0]
    v = jnp.where(mask, pts[:, dim], jnp.inf)
    order = jnp.argsort(v)  # stable -> tie-break by original index
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    nvalid = jnp.maximum(jnp.sum(mask), 1)
    return jnp.clip((ranks * p) // nvalid, 0, p - 1).astype(jnp.int32)


# --------------------------------------------------------------------------
# Partition-count helpers (paper §5.2: p is m^d for GRID, m^(d-1) for
# ANGULAR — choose m to get closest to the target p)
# --------------------------------------------------------------------------

def grid_num_parts(m: int, d: int) -> int:
    return m ** d


def angular_num_parts(m: int, d: int) -> int:
    return m ** (d - 1)


def slices_for_target_parts(target_p: int, dims: int) -> int:
    """Closest m >= 1 such that m^dims ~ target_p."""
    m = max(1, round(target_p ** (1.0 / dims)))
    best, best_gap = m, abs(m ** dims - target_p)
    for cand in (m - 1, m + 1, m + 2):
        if cand >= 1 and abs(cand ** dims - target_p) < best_gap:
            best, best_gap = cand, abs(cand ** dims - target_p)
    return best


# --------------------------------------------------------------------------
# Routing: tuples -> fixed-capacity buckets
# --------------------------------------------------------------------------

def bucketize(pts: jnp.ndarray, mask: jnp.ndarray, ids: jnp.ndarray, p: int,
              capacity: int) -> Buckets:
    """Route tuples to (p, capacity) buckets with validity masks.

    Stable sort by partition id (invalid rows sort to a virtual partition
    p), positions within a partition via searchsorted on the sorted ids,
    rows beyond capacity are dropped and flagged as overflow.
    """
    n, d = pts.shape
    ids_eff = jnp.where(mask, ids, p).astype(jnp.int32)
    order = jnp.argsort(ids_eff)
    ids_s = ids_eff[order]
    pts_s = pts[order]
    mask_s = mask[order]
    pos = jnp.arange(n, dtype=jnp.int32) - jnp.searchsorted(
        ids_s, ids_s, side="left").astype(jnp.int32)
    ok = mask_s & (ids_s < p) & (pos < capacity)
    dest = jnp.where(ok, ids_s * capacity + pos, p * capacity)
    flat = jnp.full((p * capacity, d), SENTINEL, pts.dtype)
    flat = flat.at[dest].set(pts_s, mode="drop")
    fmask = jnp.zeros((p * capacity,), jnp.bool_)
    fmask = fmask.at[dest].set(True, mode="drop")
    counts = jax.ops.segment_sum(mask.astype(jnp.int32),
                                 jnp.where(mask, ids, p).astype(jnp.int32),
                                 num_segments=p + 1)[:p]
    overflow = jnp.any(counts > capacity)
    return Buckets(flat.reshape(p, capacity, d),
                   fmask.reshape(p, capacity), counts, overflow)
