"""Filtering layers (paper §3.2 Grid Filtering, §4.1 Representative
Filtering)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dominance import (apply_sentinel, dominated_mask,
                                  monotone_score, region_volume)
from repro.core.partition import grid_cell_coords

__all__ = ["grid_filter", "select_representatives",
           "filter_by_representatives", "GridFilterResult"]


class GridFilterResult(NamedTuple):
    mask: jnp.ndarray            # updated tuple validity
    pruned_cells: jnp.ndarray    # (m,)*d bool — cells disregarded entirely
    dropped: jnp.ndarray         # () int32 tuples dropped


def _exclusive_cumor(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """OR of strictly-earlier entries along `axis` (x in {0,1})."""
    c = jnp.cumsum(x.astype(jnp.int32), axis=axis)
    return (c - x.astype(jnp.int32)) > 0


def grid_filter(pts: jnp.ndarray, mask: jnp.ndarray, m: int,
                ) -> GridFilterResult:
    """Grid Filtering (paper §3.2): a cell strictly grid-dominated by an
    occupied cell is disregarded entirely. Composing exclusive cum-ORs
    along every axis yields exactly 'exists occupied cell with all
    coordinates strictly smaller'."""
    n, d = pts.shape
    coords = grid_cell_coords(pts, m)
    idx = tuple(coords[:, i] for i in range(d))
    occ = jnp.zeros((m,) * d, jnp.int32).at[idx].add(
        mask.astype(jnp.int32)) > 0
    strict = occ
    for axis in range(d):
        strict = _exclusive_cumor(strict, axis)
    keep = mask & ~strict[idx]
    return GridFilterResult(keep, strict,
                            jnp.sum(mask) - jnp.sum(keep))


def select_representatives(pts: jnp.ndarray, mask: jnp.ndarray, k: int, *,
                           strategy: str = "sorted",
                           key: jax.Array | None = None,
                           impl: str = "auto"):
    """Pick k representative tuples (paper §4.1) and drop the dominated
    ones among them before they are shared as meta-information.

    Strategies: 'sorted' (first-k in monotone-score order — skyline-heavy
    by the topological-sort property), 'region' (largest dominance-region
    volume prod(1 - t[i]); requires [0,1] data), 'random' (baseline).
    """
    if strategy == "sorted":
        merit = -monotone_score(pts, mask)          # larger = better
    elif strategy == "region":
        merit = jnp.where(mask, region_volume(pts), -jnp.inf)
    elif strategy == "random":
        assert key is not None, "random strategy needs a PRNG key"
        merit = jnp.where(mask, jax.random.uniform(key, (pts.shape[0],)),
                          -jnp.inf)
    else:
        raise ValueError(f"unknown representative strategy {strategy!r}")
    merit = jnp.where(mask, merit, -jnp.inf)
    # tiny partitions (e.g. streaming chunks smaller than rep_k) cannot
    # yield more representatives than they hold rows
    _, idx = jax.lax.top_k(merit, min(k, pts.shape[0]))
    repmask = mask[idx]
    # a partition with fewer than k valid rows — down to none at all (an
    # all-expired epoch, a fully masked streaming chunk) — selects filler
    # rows; sentinel-fill them so arbitrary point data never leaks into
    # the shared representative pool (the repo-wide invalid-row
    # convention, repro.core.dominance)
    reps = apply_sentinel(pts[idx], repmask)
    repmask = repmask & ~dominated_mask(reps, reps, repmask, impl=impl)
    return reps, repmask


def filter_by_representatives(pts: jnp.ndarray, mask: jnp.ndarray,
                              reps: jnp.ndarray, repmask: jnp.ndarray, *,
                              impl: str = "auto") -> jnp.ndarray:
    """Delete any tuple dominated by a representative (paper §4.1)."""
    return mask & ~dominated_mask(pts, reps, repmask, impl=impl)
