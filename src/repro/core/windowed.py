"""Sliding-window skyline maintenance (`WindowedSkylineState`).

The insert-only ``SkylineState`` of `repro.core.incremental` cannot
expire data: evicting a skyline member can *un-dominate* tuples it
previously suppressed, so exact deletion needs retained candidates (the
continuous-skyline literature surveyed in PAPERS.md). This module keeps
the retained candidates **epoch-partitioned**: the live window is a ring
of E epoch sub-states, each a packed ``SkylineState``-style buffer
holding the skyline *of the tuples that arrived in that epoch* —
including members currently dominated by other epochs. Eviction happens
only *within* an epoch (same-epoch tuples expire together, so a
same-epoch dominator outlives everything it suppresses — dropping the
dominated tuple is permanently safe); cross-epoch dominance is resolved
at **merge-on-read**.

  ``WindowedSkylineState`` — E ring slots of packed epoch antichains
                      (+ per-epoch stats) and two ring scalars: ``head``
                      (the slot receiving arrivals) and ``active`` (live
                      epoch count). Leaves optionally carry a leading Q
                      axis (Q windows advancing on a shared ring clock).
  ``insert_chunk``  — route an arriving chunk into the head epoch: the
                      ordinary incremental insert (pre-filter, reduce,
                      evict, compact) restricted to the head sub-state.
  ``advance_epoch`` — open the next ring slot as the new head; when the
                      ring is full this *expires* the tail epoch in O(1)
                      (clear one slot — nothing is recomputed).
  ``expire_epoch``  — drop the tail slot without opening a new epoch
                      (expiring the only epoch empties it in place).
  ``finalize``      — merge the E epoch antichains on read through the
                      *existing* fused merge (`repro.core.parallel.
                      merge_stage`, sequential or NoSeq): each epoch
                      plays the role of a partition whose local skyline
                      is already resolved, so the read is exactly the
                      paper's partition-then-merge structure. The result
                      is canonical (total order) and bit-for-bit equal
                      to the one-shot fused skyline of exactly the
                      unexpired tuples, for any chunking and any expiry
                      schedule (tests/test_windowed.py).

Exactness: each epoch slot holds SKY(arrivals of that epoch) by the
incremental-insert invariant; dropping within-epoch dominated tuples is
safe because their dominators share their expiry time (transitivity
closes dominator chains inside the epoch). The union of the E epoch
skylines therefore dominates-out exactly what the full unexpired
multiset would, so SKY(union of epoch skylines) = SKY(unexpired tuples)
— which is what merge-on-read computes.

For the NoSeq merge the epochs carry no inter-partition order (any two
epochs can cross-dominate), so the potential-dominator mask is the
``random``-strategy one (every other epoch) regardless of the config's
partitioning strategy — see ``_merge_cfg``.

Ring scalars are traced (int32 leaves of the state), so one compiled
insert and one compiled merge-on-read serve every head position and
expiry schedule (`parallel.trace_count("winsert"/"wmerge")` observes the
bound).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import incremental as inc
from repro.core import parallel as par
from repro.core.dominance import SENTINEL
from repro.core.parallel import SkyConfig
from repro.core.sfs import SkyBuffer

__all__ = ["WindowedSkylineState", "init_window_state", "window_epochs",
           "epoch_rows", "ring_advance", "ring_tail", "insert_chunk",
           "advance_epoch", "expire_epoch",
           "finalize", "insert_window_fn", "insert_window_batch_fn",
           "advance_epoch_fn", "expire_epoch_fn", "finalize_window_fn",
           "window_tick_fn", "window_counters"]


class WindowedSkylineState(NamedTuple):
    """Ring of E epoch sub-states, resident on device between chunks.

    Epoch leaves are ``(E, ...)`` or ``(Q, E, ...)`` (Q live windows on
    a shared ring clock). Expired/unopened slots are fully masked, so
    the flattened ring is always exactly the retained-candidate set of
    the live window.
    """
    points: jnp.ndarray    # (E, C, d) or (Q, E, C, d) packed epoch members
    mask: jnp.ndarray      # (E, C) or (Q, E, C) bool validity
    count: jnp.ndarray     # (E,) or (Q, E) int32 — per-epoch antichain size
    overflow: jnp.ndarray  # (E,) or (Q, E) bool — epoch capacity exceeded
    seen: jnp.ndarray      # (E,) or (Q, E) int32 — valid tuples fed
    chunks: jnp.ndarray    # (E,) or (Q, E) int32 — inserts absorbed
    head: jnp.ndarray      # () int32 — ring slot receiving arrivals
    active: jnp.ndarray    # () int32 — live epochs (1..E)


def window_epochs(state: WindowedSkylineState) -> int:
    """Static ring length E of a windowed state."""
    return state.points.shape[-3]


def _epoch_axis(state: WindowedSkylineState) -> int:
    """Position of the epoch axis (0 unbatched, 1 with a leading Q)."""
    return state.points.ndim - 3


def epoch_rows(cfg: SkyConfig, epoch_capacity: int = 0) -> int:
    """Row count of one epoch slot: the per-epoch retained-candidate
    capacity (rounded to the dominance block), defaulting to the full
    state capacity. Epoch fronts are typically far smaller than the
    window front budget — sizing the slots to them shrinks every
    per-insert pass (pre-filter, eviction, compaction) and the
    merge-on-read union; an epoch front outgrowing its rows sets the
    overflow flag, exactly like the full-capacity case."""
    if not epoch_capacity:
        return inc.state_capacity(cfg)
    block = min(cfg.block, max(epoch_capacity, 1))
    return min(-(-max(epoch_capacity, 1) // block) * block,
               inc.state_capacity(cfg))


def init_window_state(cfg: SkyConfig, d: int, *, epochs: int,
                      dtype=jnp.float32, q: int | None = None,
                      epoch_capacity: int = 0) -> WindowedSkylineState:
    """Empty E-epoch window over ``d``-attribute tuples; ``q`` adds a
    leading batch axis (q windows sharing one ring clock).
    ``epoch_capacity`` bounds each epoch's retained-candidate buffer
    (default: the full window capacity) — see `epoch_rows`."""
    if epochs < 1:
        raise ValueError(f"need at least one epoch, got {epochs}")
    lead = () if q is None else (q,)
    c = epoch_rows(cfg, epoch_capacity)
    return WindowedSkylineState(
        points=jnp.full(lead + (epochs, c, d), SENTINEL, dtype),
        mask=jnp.zeros(lead + (epochs, c), jnp.bool_),
        count=jnp.zeros(lead + (epochs,), jnp.int32),
        overflow=jnp.zeros(lead + (epochs,), jnp.bool_),
        seen=jnp.zeros(lead + (epochs,), jnp.int32),
        chunks=jnp.zeros(lead + (epochs,), jnp.int32),
        head=jnp.int32(0),
        active=jnp.int32(1))


# --------------------------------------------------------------------------
# Ring-slot plumbing (traced epoch index -> one compiled program covers
# every head position)
# --------------------------------------------------------------------------

_EPOCH_LEAVES = ("points", "mask", "count", "overflow", "seen", "chunks")


def _sub_state(state: WindowedSkylineState, idx, axis: int,
               ) -> inc.SkylineState:
    """The `SkylineState` living in ring slot ``idx``."""
    return inc.SkylineState(*(
        jax.lax.dynamic_index_in_dim(getattr(state, name), idx, axis,
                                     keepdims=False)
        for name in _EPOCH_LEAVES))


def _set_sub(state: WindowedSkylineState, sub: inc.SkylineState, idx,
             axis: int) -> WindowedSkylineState:
    """Write ``sub`` back into ring slot ``idx``."""
    new = {name: jax.lax.dynamic_update_index_in_dim(
        getattr(state, name), getattr(sub, name), idx, axis)
        for name in _EPOCH_LEAVES}
    return state._replace(**new)


def _blank_sub(state: WindowedSkylineState, axis: int) -> inc.SkylineState:
    """An empty sub-state shaped like one ring slot of ``state``."""
    def one(name):
        x = getattr(state, name)
        shape = x.shape[:axis] + x.shape[axis + 1:]
        if name == "points":
            return jnp.full(shape, SENTINEL, x.dtype)
        return jnp.zeros(shape, x.dtype)
    return inc.SkylineState(*(one(name) for name in _EPOCH_LEAVES))


def _clear_slot(state: WindowedSkylineState, idx,
                axis: int) -> WindowedSkylineState:
    return _set_sub(state, _blank_sub(state, axis), idx, axis)


# --------------------------------------------------------------------------
# Insert: the incremental insert, restricted to the head epoch
# --------------------------------------------------------------------------

def _winsert(state: WindowedSkylineState, pts, mask, key, *,
             cfg: SkyConfig, mesh, axis_name: str):
    """One window's insert: pre-filter/evict run against the *head
    epoch only* — cross-epoch dominance is deliberately left to
    merge-on-read (an older-epoch dominator may expire first)."""
    sub = _sub_state(state, state.head, 0)
    sub, stats = inc._insert(sub, pts, mask, key, cfg=cfg, mesh=mesh,
                             axis_name=axis_name)
    return _set_sub(state, sub, state.head, 0), stats


def _winsert_batch(state: WindowedSkylineState, pts, mask, keys, *,
                   cfg: SkyConfig, mesh, q_axis: str, w_axis: str):
    """Q windows advanced in one dispatch (shared ring clock): the head
    sub-states form a batched `SkylineState` and take the ordinary
    batched insert — vmap without a mesh, the 2-D (queries x workers)
    program with one."""
    sub = _sub_state(state, state.head, 1)
    sub, stats = inc._insert_batch(sub, pts, mask, keys, cfg=cfg,
                                   mesh=mesh, q_axis=q_axis, w_axis=w_axis)
    return _set_sub(state, sub, state.head, 1), stats


# --------------------------------------------------------------------------
# Ring ops: O(1) epoch lifecycle — clear one slot, move two scalars
# --------------------------------------------------------------------------

def ring_advance(head, active, epochs: int):
    """Ring clock after opening a new head epoch: ``(new_head,
    new_active, expired)`` — ``expired`` iff the ring was full, i.e. the
    claimed slot held the tail epoch. The single definition of the
    clock arithmetic; works on traced scalars, host ints, AND numpy
    per-tenant clock vectors (host callers — the slab-backed engine
    streams — must stay device-free, so no jnp op may touch
    plain-int/numpy inputs)."""
    if isinstance(active, jax.Array):
        clamp = jnp.minimum
    elif isinstance(active, np.ndarray):
        clamp = np.minimum
    else:
        clamp = min
    return (head + 1) % epochs, clamp(active + 1, epochs), \
        active >= epochs


def ring_tail(head, active, epochs: int):
    """Ring slot currently holding the tail (oldest live) epoch."""
    return (head - active + 1) % epochs


def _expired_tuples(state: WindowedSkylineState, idx, axis: int):
    cnt = jax.lax.dynamic_index_in_dim(state.count, idx, axis,
                                       keepdims=False)
    return jnp.sum(cnt).astype(jnp.int32)


def _advance(state: WindowedSkylineState):
    """Open the next ring slot as head. With the ring full, the slot
    being claimed holds the tail epoch: clearing it IS the expiry —
    O(1), nothing recomputed (the un-domination it may cause is
    resolved by the next merge-on-read)."""
    epochs = window_epochs(state)
    axis = _epoch_axis(state)
    new_head, new_active, expired = ring_advance(state.head, state.active,
                                                 epochs)
    stats = {"expired_epoch": expired,
             "expired_tuples": _expired_tuples(state, new_head, axis)}
    state = _clear_slot(state, new_head, axis)
    return state._replace(head=new_head, active=new_active), stats


def _expire(state: WindowedSkylineState):
    """Drop the tail epoch without opening a new one. Expiring the only
    live epoch clears it in place (the window empties but stays open
    for arrivals)."""
    epochs = window_epochs(state)
    axis = _epoch_axis(state)
    tail = ring_tail(state.head, state.active, epochs)
    stats = {"expired_tuples": _expired_tuples(state, tail, axis)}
    state = _clear_slot(state, tail, axis)
    return state._replace(active=jnp.maximum(state.active - 1, 1)), stats


# --------------------------------------------------------------------------
# Merge-on-read: the E epoch antichains through the existing fused merge
# --------------------------------------------------------------------------

def _merge_cfg(cfg: SkyConfig) -> SkyConfig:
    """Epochs carry no inter-partition order (any pair can
    cross-dominate), so the NoSeq potential-dominator mask must be the
    ``random``-strategy one: every other epoch. The sequential merge
    never reads the strategy."""
    if cfg.noseq and cfg.strategy != "random":
        return dataclasses.replace(cfg, strategy="random")
    return cfg


def _merge_epochs(points, mask, *, cfg: SkyConfig) -> SkyBuffer:
    """SKY(union of epoch antichains) via `parallel.merge_stage`, with
    each epoch standing in for a partition whose local skyline is
    already resolved. (E, C, d)/(E, C) -> canonical SkyBuffer.

    This call passes no workers axis, so `merge='tree'` resolves to the
    identical flat math by design: the E antichains are device-local
    (the ring is replicated state, not sharded data) and there is
    nothing to permute — merge-on-read stays collective-free, which is
    what lets the batched snapshot vmap over queries under a mesh. The
    tree schedule still serves windowed pipelines where it matters: the
    head-epoch *insert* runs the full partition/local/merge reduce
    through `repro.core.incremental`, workers collectives included."""
    epochs, _, d = points.shape
    sky = SkyBuffer(points, mask,
                    jnp.sum(mask, -1).astype(jnp.int32),
                    jnp.zeros((epochs,), jnp.bool_))
    meta = {"p": epochs, "m": 0,
            "cells": jnp.zeros((epochs, d), jnp.int32),
            "part_idx": jnp.arange(epochs, dtype=jnp.int32)}
    final, _ = par.merge_stage(sky, meta, _merge_cfg(cfg))
    return final


def _wfinalize(state: WindowedSkylineState, *, cfg: SkyConfig) -> SkyBuffer:
    """Canonical window snapshot: merge-on-read over the ring, fitted to
    the state row count — bit-for-bit the one-shot fused answer over
    exactly the unexpired tuples (both emit the same canonical total
    order; see tests/test_windowed.py)."""
    final = _merge_epochs(state.points, state.mask, cfg=cfg)
    pts, mask = inc._fit_rows(final.points, final.mask,
                              inc.state_capacity(cfg))
    overflow = final.overflow | jnp.any(state.overflow)
    return SkyBuffer(pts, mask, final.count, overflow)


def _wfinalize_batch(state: WindowedSkylineState, *, cfg: SkyConfig,
                     mesh, q_axis: str) -> SkyBuffer:
    """Q windows snapshot in one dispatch. The merge input (E packed
    antichains per window) is collective-free, so with a mesh the batch
    just carries a ``queries``-axis sharding constraint under vmap."""
    points, mask = state.points, state.mask
    if mesh is not None:
        spec = NamedSharding(mesh, P(q_axis))
        points = jax.lax.with_sharding_constraint(points, spec)
        mask = jax.lax.with_sharding_constraint(mask, spec)
    final = jax.vmap(lambda p, m: _merge_epochs(p, m, cfg=cfg))(points,
                                                                mask)
    c = inc.state_capacity(cfg)
    pts, fmask = inc._fit_rows(final.points, final.mask, c)
    overflow = final.overflow | jnp.any(state.overflow, axis=-1)
    return SkyBuffer(pts, fmask, final.count, overflow)


# --------------------------------------------------------------------------
# Jitted entry points, cached per (cfg, mesh, axes) — the ring scalars
# are traced, so every head position and expiry schedule shares ONE
# compiled insert and ONE compiled merge-on-read per shape bucket
# (trace labels "winsert", "winsert_batch", "wmerge", "wmerge_batch",
# "wtick").
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def insert_window_fn(cfg: SkyConfig, mesh: jax.sharding.Mesh | None = None,
                     axis_name: str = "workers"):
    """Jitted ``(state, pts, mask, key) -> (state', stats)`` routing the
    chunk into the head epoch of one live window."""

    def run(state, pts, mask, key):
        par._TRACE_EVENTS["winsert"] += 1
        return _winsert(state, pts, mask, key, cfg=cfg, mesh=mesh,
                        axis_name=axis_name)

    # single-owner update: the ring's buffers are reused for state'
    # (callers rebind); cfg.donate=False keeps copy semantics for A/B
    return jax.jit(run, donate_argnums=(0,)) if cfg.donate else jax.jit(run)


@functools.lru_cache(maxsize=None)
def insert_window_batch_fn(cfg: SkyConfig,
                           mesh: jax.sharding.Mesh | None = None,
                           q_axis: str = "queries",
                           w_axis: str = "workers"):
    """Jitted ``(state, pts (Q, N, d), mask (Q, N), keys (Q, ...)) ->
    (state', stats)`` advancing Q live windows in one dispatch."""

    def run(state, pts, mask, keys):
        par._TRACE_EVENTS["winsert_batch"] += 1
        return _winsert_batch(state, pts, mask, keys, cfg=cfg, mesh=mesh,
                              q_axis=q_axis, w_axis=w_axis)

    return jax.jit(run, donate_argnums=(0,)) if cfg.donate else jax.jit(run)


@functools.lru_cache(maxsize=None)
def advance_epoch_fn(donate: bool = True):
    """Jitted ``state -> (state', stats)``: next slot becomes head; a
    full ring expires its tail epoch in O(1). ``donate`` is a cache key
    (these factories take no cfg): the default reuses the ring's
    buffers in place, mirroring `cfg.donate`."""

    def run(state):
        par._TRACE_EVENTS["wtick"] += 1
        return _advance(state)

    return jax.jit(run, donate_argnums=(0,)) if donate else jax.jit(run)


@functools.lru_cache(maxsize=None)
def expire_epoch_fn(donate: bool = True):
    """Jitted ``state -> (state', stats)``: drop the tail epoch."""

    def run(state):
        par._TRACE_EVENTS["wtick"] += 1
        return _expire(state)

    return jax.jit(run, donate_argnums=(0,)) if donate else jax.jit(run)


@functools.lru_cache(maxsize=None)
def finalize_window_fn(cfg: SkyConfig, batched: bool = False,
                       mesh: jax.sharding.Mesh | None = None,
                       q_axis: str = "queries"):
    """Jitted ``state -> SkyBuffer`` merge-on-read snapshot
    (non-destructive: the ring keeps absorbing chunks afterwards)."""
    if batched:
        def run(state):
            par._TRACE_EVENTS["wmerge_batch"] += 1
            return _wfinalize_batch(state, cfg=cfg, mesh=mesh,
                                    q_axis=q_axis)
    else:
        def run(state):
            par._TRACE_EVENTS["wmerge"] += 1
            return _wfinalize(state, cfg=cfg)
    # read-only overlay: the snapshot must NOT consume the ring — the
    # caller keeps feeding the same state afterwards, so the operand is
    # legitimately shared, never donated
    # skylint: disable=R6
    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def window_tick_fn(cfg: SkyConfig, mesh: jax.sharding.Mesh | None = None,
                   axis_name: str = "workers"):
    """One serving tick as ONE dispatch: ``(state, pts, mask, key,
    advance) -> (state', front, stats)`` — optionally rotate the ring
    (``advance`` is traced, so both tick kinds share the program),
    insert the arrivals into the head epoch, and emit the merged window
    front. This is the per-tick hot path of the sliding_window
    benchmark: fusing the three steps drops two dispatch round-trips per
    tick."""

    def run(state, pts, mask, key, advance):
        par._TRACE_EVENTS["wtick_fused"] += 1
        state = jax.lax.cond(advance, lambda s: _advance(s)[0],
                             lambda s: s, state)
        state, stats = _winsert(state, pts, mask, key, cfg=cfg, mesh=mesh,
                                axis_name=axis_name)
        return state, _wfinalize(state, cfg=cfg), stats

    return jax.jit(run, donate_argnums=(0,)) if cfg.donate else jax.jit(run)


# --------------------------------------------------------------------------
# Convenience wrappers (mirror repro.core.incremental)
# --------------------------------------------------------------------------

def insert_chunk(state: WindowedSkylineState, pts: jnp.ndarray,
                 mask: jnp.ndarray | None = None, *, cfg: SkyConfig,
                 key: jax.Array | None = None,
                 mesh: jax.sharding.Mesh | None = None,
                 axis_name: str = "workers"):
    """Route one arriving chunk into the head epoch (batched when the
    state carries a leading Q axis)."""
    batched = state.points.ndim == 4
    if mask is None:
        mask = jnp.ones(pts.shape[:-1], jnp.bool_)
    if key is None:
        key = jax.random.PRNGKey(0)
    if batched:
        q = state.points.shape[0]
        keys = key if key.ndim == 2 else jax.random.split(key, q)
        return insert_window_batch_fn(cfg, mesh, w_axis=axis_name)(
            state, pts, mask, keys)
    return insert_window_fn(cfg, mesh, axis_name)(state, pts, mask, key)


def advance_epoch(state: WindowedSkylineState, *, donate: bool = True):
    """Open a new head epoch (expires the tail when the ring is full).
    The state is donated by default — rebind the result."""
    return advance_epoch_fn(donate)(state)


def expire_epoch(state: WindowedSkylineState, *, donate: bool = True):
    """Drop the tail epoch in O(1). The state is donated by default —
    rebind the result."""
    return expire_epoch_fn(donate)(state)


def finalize(state: WindowedSkylineState, *, cfg: SkyConfig,
             mesh: jax.sharding.Mesh | None = None,
             q_axis: str = "queries") -> SkyBuffer:
    """Canonical merge-on-read snapshot of one or Q live windows."""
    batched = state.points.ndim == 4
    return finalize_window_fn(cfg, batched, mesh if batched else None,
                              q_axis)(state)


def window_counters(state: WindowedSkylineState) -> dict[str, Any]:
    """Window-level running stats (sums over the live ring; device
    arrays — host sync only when read)."""
    ax = _epoch_axis(state)
    return {"retained": jnp.sum(state.count, axis=ax),
            "seen": jnp.sum(state.seen, axis=ax),
            "chunks": jnp.sum(state.chunks, axis=ax),
            "overflow": jnp.any(state.overflow, axis=ax),
            "head": state.head, "active": state.active}
