"""Public skyline API."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.parallel import SkyConfig, parallel_skyline
from repro.core.sfs import SkyBuffer, block_sfs, naive_skyline_mask

__all__ = ["skyline", "skyline_mask_exact", "parallel_skyline", "SkyConfig",
           "SkyBuffer"]


def skyline(pts: jnp.ndarray, mask: jnp.ndarray | None = None, *,
            capacity: int | None = None, block: int = 256,
            impl: str = "auto") -> SkyBuffer:
    """Sequential skyline via block-SFS (paper Algorithm 1)."""
    cap = capacity or pts.shape[0]
    return block_sfs(pts, mask, capacity=cap, block=block, impl=impl)


def skyline_mask_exact(pts: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """O(N^2) oracle membership mask (tests / small inputs)."""
    return naive_skyline_mask(pts, mask)
