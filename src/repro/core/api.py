"""Public skyline API.

`skyline` / `skyline_mask_exact` are the sequential entry points;
`parallel_skyline` runs the fused partition+local+merge program (one jit,
optionally shard_mapped over a worker mesh — see repro.core.parallel).
For many concurrent queries use `repro.serve.engine.SkylineEngine`, which
batches them into one vmapped dispatch of the same program. For data that
arrives over time, `init_state` / `insert_chunk` / `finalize`
(repro.core.incremental) maintain a device-resident running skyline whose
finalized snapshot is bit-for-bit the one-shot answer.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.dominance import SENTINEL
from repro.core.incremental import (SkylineState, finalize, init_state,
                                    insert_chunk)
from repro.core.parallel import SkyConfig, parallel_skyline
from repro.core.sfs import SkyBuffer, block_sfs, naive_skyline_mask

__all__ = ["skyline", "skyline_mask_exact", "parallel_skyline", "SkyConfig",
           "SkyBuffer", "SkylineState", "init_state", "insert_chunk",
           "finalize"]


def skyline(pts: jnp.ndarray, mask: jnp.ndarray | None = None, *,
            capacity: int | None = None, block: int = 256,
            impl: str = "auto", wtile: int = 0) -> SkyBuffer:
    """Sequential skyline via block-SFS (paper Algorithm 1).

    Degenerate inputs are well-formed: ``n == 0`` (or an explicit
    ``capacity=0``) returns an empty buffer instead of tracing a
    zero-row window through block_sfs, and all-masked inputs yield
    ``count == 0`` with no valid rows.
    """
    n, d = pts.shape
    cap = n if capacity is None else capacity
    if n == 0 or cap == 0:
        cap = max(cap, 1)
        return SkyBuffer(jnp.full((cap, d), SENTINEL, pts.dtype),
                         jnp.zeros((cap,), jnp.bool_),
                         jnp.zeros((), jnp.int32),
                         jnp.zeros((), jnp.bool_))
    return block_sfs(pts, mask, capacity=cap, block=block, impl=impl,
                     wtile=wtile)


def skyline_mask_exact(pts: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """O(N^2) oracle membership mask (tests / small inputs)."""
    return naive_skyline_mask(pts, mask)
