"""NoSeq (paper §4.2): fully parallel second phase.

After phase 1, let u = union of local skylines u_i. Worker i removes its
globally-dominated tuples by testing u_i only against its *potential
dominators* pd_i subset of u \\ u_i (Proposition 2):

  RANDOM / ANGULAR : pd_i = u \\ u_i                (no inter-partition order)
  SLICED           : pd_i = { u_j : j < i }         (slice order is
                      topological w.r.t. the sliced dimension)
  GRID             : pd_i = { u_j : c_j <=_G c_i }  (a dominator's cell
                      coordinates are <= in every dimension)

The masks below are evaluated per reference *row* of the gathered buffer
(p * C rows), given the row's source partition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dominance import dominated_mask

__all__ = ["pd_row_mask", "relative_skyline_mask", "relative_rows_mask"]


def pd_row_mask(strategy: str, own_part: jnp.ndarray,
                ref_parts: jnp.ndarray,
                own_cell: jnp.ndarray | None = None,
                ref_cells: jnp.ndarray | None = None) -> jnp.ndarray:
    """(R,) bool — which gathered rows are potential dominators for the
    worker that owns partition `own_part`."""
    not_self = ref_parts != own_part
    if strategy in ("random", "angular"):
        return not_self
    if strategy == "sliced":
        return ref_parts < own_part
    if strategy == "grid":
        assert own_cell is not None and ref_cells is not None
        weak = jnp.all(ref_cells <= own_cell[None, :], axis=-1)
        return weak & not_self
    raise ValueError(f"unknown strategy {strategy!r}")


def relative_skyline_mask(u_i: jnp.ndarray, mask_i: jnp.ndarray,
                          refs: jnp.ndarray, ref_mask: jnp.ndarray,
                          pd_mask: jnp.ndarray, *,
                          impl: str = "auto") -> jnp.ndarray:
    """SKY_{pd_i}(u_i) membership mask (paper Definition 4)."""
    dom = dominated_mask(u_i, refs, ref_mask & pd_mask, impl=impl)
    return mask_i & ~dom


def relative_rows_mask(pts: jnp.ndarray, mask: jnp.ndarray,
                       parts: jnp.ndarray, cells: jnp.ndarray, *,
                       strategy: str, block: int = 256) -> jnp.ndarray:
    """Per-ROW relative-skyline mask of a mixed-origin buffer.

    The flat NoSeq merge evaluates pd_i once per *worker* (every row of
    u_i shares one partition). The tree merge's intermediate buffers mix
    rows from many partitions, so each row carries its own partition id
    (and grid cell) and the potential-dominator relation is evaluated
    per (candidate row, reference row) pair — the same pd predicate as
    `pd_row_mask`, just row-wise on both sides. The dominance test is
    pure boolean comparisons, so the outcome is bit-identical to the
    blocked kernel's for the same pair set; candidates walk in blocks of
    ``block`` rows (a `lax.map`) to keep the pairwise footprint at
    O(block x R) like the kernel's.
    """
    r, d = pts.shape
    b = min(block, max(r, 1))
    nb = -(-r // b)
    pad = nb * b - r
    cp = jnp.pad(pts, ((0, pad), (0, 0)))
    cm = jnp.pad(mask, (0, pad))
    cparts = jnp.pad(parts, (0, pad))
    ccells = jnp.pad(cells, ((0, pad), (0, 0)))

    def one(args):
        x, xm, xp, xc = args
        le = jnp.all(pts[None, :, :] <= x[:, None, :], axis=-1)
        lt = jnp.any(pts[None, :, :] < x[:, None, :], axis=-1)
        if strategy in ("random", "angular"):
            pd = parts[None, :] != xp[:, None]
        elif strategy == "sliced":
            pd = parts[None, :] < xp[:, None]
        elif strategy == "grid":
            pd = (jnp.all(cells[None, :, :] <= xc[:, None, :], axis=-1)
                  & (parts[None, :] != xp[:, None]))
        else:
            raise ValueError(f"unknown strategy {strategy!r}")
        dom = jnp.any(le & lt & mask[None, :] & pd, axis=1)
        return xm & ~dom

    out = jax.lax.map(one, (cp.reshape(nb, b, d), cm.reshape(nb, b),
                            cparts.reshape(nb, b),
                            ccells.reshape(nb, b, ccells.shape[-1])))
    return out.reshape(-1)[:r]
