"""Parallel skyline computation (paper Algorithm 2) on a JAX device mesh.

The three phases map onto SPMD as (DESIGN.md §3):

  partition  — partition-id map + `bucketize` routing (global data prep,
               the analogue of Spark's shuffle),
  local      — per-partition block-SFS: ONE fused-sweep dispatch for the
               whole partition batch a device owns
               (`repro.core.sfs.local_skyline_batch` -> the kernel
               backend's sfs sweep), `shard_map` over the `workers` axis,
  merge      — either the paper's sequential pass (gather + one more
               fused-sweep call on the compacted union) or NoSeq
               (all_gather of the local skylines + per-worker
               relative-skyline filtering against pd_i).

Representative Filtering (paper §4.1) selects k representatives per
partition, all_gathers them, removes dominated representatives, and
pre-filters every partition before local skyline computation.

Execution model: all three phases run as **one jitted SPMD program**
(`fused_skyline_fn`). Partitioning and routing are traced into the same
computation as the shard_mapped local+merge phases, with
`with_sharding_constraint` handing the routed buckets to the `workers`
mesh axis — there is no host round-trip or `device_put` between stages,
and the returned stats pytree stays on device until the caller reads it.
Compiled programs are cached per (cfg, mesh, axis_name); jit's own cache
handles shapes, so repeated same-shape queries never retrace (observable
via `trace_count()`).

A single-device semantic mode (mesh=None) runs the identical math with
plain vmaps — used by unit tests, the batched multi-query engine
(`repro.serve.engine`, which vmaps this program over queries), and CPU
benchmarks.

For engine batches of *large* queries there is additionally a 2-D
(queries x workers) program (`fused_skyline_batch_fn` with a mesh): the
query batch is sharded over a `queries` mesh axis and, within each query
shard, every query's partitions are sharded over the `workers` axis —
the distributed-skyline regime of Zhang & Zhang combined with query
batching. Axis names are parameters throughout, so the same program
embeds in larger meshes.

Both one-shot programs are thin wrappers over the device-resident
`SkylineState` abstraction of `repro.core.incremental` ("insert
everything into an empty state"); streaming callers keep the state
between chunks instead of discarding it.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import filtering, noseq, partition
from repro.core.dominance import apply_sentinel, canonical_order
from repro.core.sfs import (SkyBuffer, block_sfs, compact, compact_order,
                            local_skyline_batch)
from repro.kernels.backend import resolve_spec

__all__ = ["SkyConfig", "parallel_skyline", "fused_skyline_fn",
           "fused_skyline_batch_fn", "effective_parts", "partition_stage",
           "local_stage", "merge_stage", "merge_rounds", "resolve_merge",
           "trace_count"]


@dataclasses.dataclass(frozen=True)
class SkyConfig:
    """Configuration of the parallel skyline pipeline."""
    strategy: str = "sliced"      # random | grid | angular | sliced
    p: int = 8                    # target #partitions (grid/angular: derived)
    m: int = 0                    # slices/dim (grid/angular); 0 = derive from p
    bucket_factor: float = 1.0    # bucket capacity = factor * ceil(n/p)
    bucket_capacity: int = 0      # explicit override (0 = use factor)
    local_capacity: int = 0       # phase-1 window capacity (0 = bucket cap)
    capacity: int = 4096          # final skyline buffer capacity
    block: int = 256              # dominance-test block size
    wtile: int = 0                # sweep window tile (0 = whole window)
    rep_filter: str | None = None  # None | sorted | region | random
    rep_k: int = 16               # representatives per partition
    noseq: bool = False           # parallel phase 2 (paper §4.2)
    grid_filter: bool = True      # grid-only pre-filter (paper §3.2)
    sliced_dim: int = 0
    impl: str = "auto"            # dominance kernel impl
    merge: str = "flat"           # union merge topology: flat | tree | auto
    donate: bool = True           # donate state/arena operands (in-place
    #                               updates; off = A/B copy semantics)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def effective_parts(cfg: SkyConfig, d: int) -> tuple[int, int]:
    """(p, m) actually used, honouring grid/angular constraints."""
    if cfg.strategy == "grid":
        m = cfg.m or partition.slices_for_target_parts(cfg.p, d)
        return partition.grid_num_parts(m, d), m
    if cfg.strategy == "angular":
        m = cfg.m or partition.slices_for_target_parts(cfg.p, max(d - 1, 1))
        return partition.angular_num_parts(m, d), m
    return cfg.p, 0


def _grid_cells(p: int, m: int, d: int) -> jnp.ndarray:
    """(p, d) cell coordinates of each grid partition index."""
    i = jnp.arange(p, dtype=jnp.int32)
    return jnp.stack([(i // (m ** k)) % m for k in range(d)], axis=1)


# --------------------------------------------------------------------------
# Stage 1: partition (global data prep)
# --------------------------------------------------------------------------

def partition_stage(pts: jnp.ndarray, mask: jnp.ndarray | None,
                    cfg: SkyConfig, key: jax.Array | None = None):
    """Partition-id map + routing into (p, C, d) buckets + meta."""
    n, d = pts.shape
    if mask is None:
        mask = jnp.ones((n,), jnp.bool_)
    if key is None:
        key = jax.random.PRNGKey(0)
    p, m = effective_parts(cfg, d)

    stats: dict[str, Any] = {}
    cells = jnp.zeros((p, d), jnp.int32)
    if cfg.strategy == "random":
        ids = partition.random_part_ids(key, n, p)
    elif cfg.strategy == "sliced":
        ids = partition.sliced_part_ids(pts, mask, p, cfg.sliced_dim)
    elif cfg.strategy == "grid":
        if cfg.grid_filter:
            gf = filtering.grid_filter(pts, mask, m)
            mask = gf.mask
            stats["grid_filter_dropped"] = gf.dropped
        ids = partition.grid_part_ids(pts, m)
        cells = _grid_cells(p, m, d)
    elif cfg.strategy == "angular":
        ids = partition.angular_part_ids(pts, m)
    else:
        raise ValueError(f"unknown strategy {cfg.strategy!r}")

    cap = cfg.bucket_capacity or max(
        1, int(cfg.bucket_factor * _ceil_div(n, p)) + 1)
    buckets = partition.bucketize(pts, mask, ids, p, cap)
    meta = {"p": p, "m": m, "cells": cells,
            "part_idx": jnp.arange(p, dtype=jnp.int32)}
    stats["bucket_counts"] = buckets.counts
    stats["bucket_overflow"] = buckets.overflow
    stats["n_valid"] = jnp.sum(mask)
    return buckets, meta, stats


# --------------------------------------------------------------------------
# Stage 2: local skylines (+ representative filtering), per worker
# --------------------------------------------------------------------------

def _select_local_reps(bufs, bmask, cfg: SkyConfig, key):
    keys = jax.random.split(key, bufs.shape[0])
    dom_impl = resolve_spec(cfg.impl).dominance
    def one(b, m, k):
        return filtering.select_representatives(
            b, m, cfg.rep_k, strategy=cfg.rep_filter, key=k, impl=dom_impl)
    return jax.vmap(one)(bufs, bmask, keys)


def local_stage(bufs, bmask, cfg: SkyConfig, *, key=None, gather=None):
    """Phase 1 on the partitions held by this worker.

    `gather` concatenates along axis 0 across workers (identity on a single
    device, lax.all_gather(tiled) under shard_map)."""
    if gather is None:
        gather = lambda x: x
    if key is None:
        key = jax.random.PRNGKey(1)
    p_local, cap, d = bufs.shape
    stats: dict[str, Any] = {}

    if cfg.rep_filter:
        dom_impl = resolve_spec(cfg.impl).dominance
        reps, rmask = _select_local_reps(bufs, bmask, cfg, key)
        pool = gather(reps).reshape(-1, d)
        pmask = gather(rmask).reshape(-1)
        # drop dominated representatives before sharing (paper §4.1)
        pmask = pmask & ~jax.vmap(
            lambda t: jnp.any((jnp.all(pool <= t, -1) &
                               jnp.any(pool < t, -1)) & pmask))(pool)
        before = jnp.sum(bmask)
        bmask = jax.vmap(lambda b, m: filtering.filter_by_representatives(
            b, m, pool, pmask, impl=dom_impl))(bufs, bmask)
        stats["rep_filter_dropped"] = before - jnp.sum(bmask)

    # Phase 1 proper: the whole partition batch through ONE fused-sweep
    # dispatch (window test + self-test + append fused; no per-pair
    # dominance launches — see repro.kernels.sfs).
    local_cap = cfg.local_capacity or cap
    sky = local_skyline_batch(bufs, bmask, capacity=local_cap,
                              block=cfg.block, impl=cfg.impl,
                              wtile=cfg.wtile)
    stats["local_sizes"] = sky.count
    stats["local_overflow"] = jnp.any(sky.overflow)
    return sky, stats


# --------------------------------------------------------------------------
# Stage 3: merge — sequential (paper Alg. 2 line 5) or NoSeq (paper §4.2),
# over one of two collective topologies: the flat all_gather union or the
# ⌈log₂(W)⌉-round pruning ppermute tree (`SkyConfig.merge`)
# --------------------------------------------------------------------------

def merge_rounds(axis_size: int) -> int:
    """⌈log₂(axis_size)⌉ — the tree merge's ppermute round count."""
    return max(int(axis_size) - 1, 0).bit_length()


def resolve_merge(cfg: SkyConfig, *, axis_size=None, p_total=None,
                  local_cap=None, d=None) -> str:
    """The single merge-topology decision point, shared by every
    execution path (one-shot, incremental insert, windowed head-epoch
    insert, the engine programs).

    ``'flat'`` / ``'tree'`` are honoured as-is; ``'auto'`` compares the
    modeled per-worker boundary elements of the two schedules — the flat
    union all_gather moves O(p x C_loc) padded rows to every worker,
    the tree moves O(capacity) rows per round over ⌈log₂(W)⌉ rounds plus
    one capacity-sized broadcast — and picks the smaller. Without a
    workers axis (``axis_size`` None or 1) the union is device-local and
    'auto' resolves to 'flat'; the engine overrides 'auto' with its
    calibrated per-bucket choice (`calibrate_shard_threshold`)."""
    if cfg.merge not in ("flat", "tree", "auto"):
        raise ValueError(f"unknown merge mode {cfg.merge!r} "
                         f"(expected flat | tree | auto)")
    if cfg.merge != "auto":
        return cfg.merge
    if not axis_size or axis_size < 2 or p_total is None:
        return "flat"
    cap = min(p_total * local_cap, max(cfg.capacity, 1))
    flat_elems = p_total * local_cap * d
    tree_elems = (merge_rounds(axis_size) + 2) * cap * (d + 1)
    return "tree" if flat_elems > tree_elems else "flat"


# wire packing: ONE tensor per ppermute round — points, the validity
# mask as a 1.0/0.0 column, and (NoSeq) per-row partition ids / grid
# cells as exact small-integer float columns (ids stay far below the
# 2^24 f32 mantissa bound)
_WIRE_UINT = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _pack_wire(pts, msk, parts=None, cells=None):
    cols = [pts, msk.astype(pts.dtype)[:, None]]
    if parts is not None:
        cols.append(parts.astype(pts.dtype)[:, None])
        cols.append(cells.astype(pts.dtype))
    return jnp.concatenate(cols, axis=1)


def _root_broadcast(wire, axis_name):
    """Replicate worker 0's buffer to the whole axis, bit-exactly.

    A float psum of where(root, x, 0) would corrupt negative zeros
    (-0.0 + 0.0 == +0.0), so the buffer is bitcast to unsigned ints —
    only the root contributes a nonzero term, making the integer sum an
    exact copy of the root's bits."""
    bits = jax.lax.bitcast_convert_type(
        wire, _WIRE_UINT[jnp.dtype(wire.dtype).itemsize])
    root = jnp.equal(jax.lax.axis_index(axis_name), 0)
    bits = jnp.where(root, bits, jnp.zeros_like(bits))
    return jax.lax.bitcast_convert_type(jax.lax.psum(bits, axis_name),
                                        wire.dtype)


def _tree_merge(sky: SkyBuffer, cfg: SkyConfig, *, part_idx_local,
                cells_local, axis_name: str, axis_size: int):
    """Hierarchical merge: ⌈log₂(W)⌉ pruning ppermute rounds.

    Round r (stride s = 2^r) sends worker i+s's compacted buffer to
    worker i for every receiver i ≡ 0 (mod 2s) — a reduce-to-root
    schedule that is exact for any worker count: a sender holds exactly
    r factors of two in its index, so it never participates again and
    its (already forwarded) buffer is never re-read. Workers outside the
    round's partial permutation receive zeros (an all-masked buffer) and
    re-sweep their own antichain, keeping the program SPMD-uniform
    without touching the result. After the rounds worker 0 holds the
    pruned union; one bit-exact psum broadcast replicates it.

    Every boundary tensor is O(capacity) rows — never the p x C_loc
    padded union the flat all_gather ships. Survivor sets match the flat
    merge exactly (dominance is transitive, so a dominator pruned
    in-round is itself dominated by a surviving row of the same buffer;
    NoSeq's potential-dominator relation is closed under that chain —
    see `noseq.relative_rows_mask`), and the shared canonical total
    order makes the output bit-for-bit equal whenever no overflow
    occurred. Overflow reduces to "union > min(p x C_loc, capacity)" in
    both modes, so the flag matches even when truncation differs."""
    p_local, local_cap, d = sky.points.shape
    w = int(axis_size)
    union_size = jax.lax.psum(jnp.sum(sky.mask), axis_name)
    flat = sky.points.reshape(-1, d)
    fmask = sky.mask.reshape(-1)
    cap_u = min(w * flat.shape[0], max(cfg.capacity, 1))
    overflow = union_size > cap_u

    if not cfg.noseq:
        # worker-local reduce: the flat merge's math restricted to this
        # worker's shard (at W=1 this IS the flat merge, bit for bit)
        own = compact(flat, fmask,
                      min(flat.shape[0], max(cfg.capacity, 1)))
        buf = block_sfs(own.points, own.mask, capacity=cfg.capacity,
                        block=cfg.block, impl=cfg.impl, wtile=cfg.wtile)
        pts, msk = buf.points, buf.mask

        dom_impl = resolve_spec(cfg.impl).dominance
        rows = pts.shape[0]
        for r in range(merge_rounds(w)):
            s = 1 << r
            perm = [(i + s, i) for i in range(0, w - s, 2 * s)]
            rcv = jax.lax.ppermute(_pack_wire(pts, msk), axis_name, perm)
            rpts, rmsk = rcv[:, :d], rcv[:, d] > 0.5
            # both sides are already antichains, so a pairwise dominance
            # cross-filter yields exactly the union's skyline without
            # re-running the sequential sweep: if a row were dropped by
            # a cross-side dominator that itself dies in-round, its
            # killer (same side as the dominator, by transitivity) would
            # contradict that side being dominance-free
            keep_own = filtering.filter_by_representatives(
                pts, msk, rpts, rmsk, impl=dom_impl)
            keep_rcv = filtering.filter_by_representatives(
                rpts, rmsk, pts, msk, impl=dom_impl)
            # survivors fit `rows` whenever the union did not overflow
            # (> capacity survivors implies union_size > cap_u, already
            # flagged above); under overflow truncation may differ from
            # the flat schedule, like every other overflow regime
            out = compact(jnp.concatenate([pts, rpts]),
                          jnp.concatenate([keep_own, keep_rcv]), rows)
            pts, msk = out.points, out.mask

        wire = _root_broadcast(_pack_wire(pts, msk), axis_name)
        pts, msk = wire[:, :d], wire[:, d] > 0.5
        pts = apply_sentinel(pts, msk)
        order = canonical_order(pts, msk)
        final = SkyBuffer(pts[order], msk[order],
                          jnp.sum(msk).astype(jnp.int32), overflow)
        return final, {"union_size": union_size}

    # NoSeq: rows keep their origin partition (and grid cell) so the
    # potential-dominator mask is evaluated per row pair in-round
    parts = jnp.repeat(part_idx_local, local_cap)
    cells = jnp.repeat(cells_local, local_cap, axis=0)
    take = min(flat.shape[0], cap_u)
    order = compact_order(fmask, take)
    pts, msk = flat[order], fmask[order]
    pparts, pcells = parts[order], cells[order]
    if take < cap_u:
        # pad to the global survivor budget so in-round survivors never
        # truncate before the union itself overflows
        pts = jnp.pad(pts, ((0, cap_u - take), (0, 0)))
        msk = jnp.pad(msk, (0, cap_u - take))
        pparts = jnp.pad(pparts, (0, cap_u - take))
        pcells = jnp.pad(pcells, ((0, cap_u - take), (0, 0)))
    # self-filter within the worker (covers the same-shard pairs the
    # flat merge tests through the full gathered reference set)
    msk = noseq.relative_rows_mask(pts, msk, pparts, pcells,
                                   strategy=cfg.strategy, block=cfg.block)

    for r in range(merge_rounds(w)):
        s = 1 << r
        perm = [(i + s, i) for i in range(0, w - s, 2 * s)]
        rcv = jax.lax.ppermute(_pack_wire(pts, msk, pparts, pcells),
                               axis_name, perm)
        cpts = jnp.concatenate([pts, rcv[:, :d]])
        cmsk = jnp.concatenate([msk, rcv[:, d] > 0.5])
        cparts = jnp.concatenate(
            [pparts, rcv[:, d + 1].astype(jnp.int32)])
        ccells = jnp.concatenate(
            [pcells, rcv[:, d + 2:].astype(jnp.int32)])
        cmsk = noseq.relative_rows_mask(cpts, cmsk, cparts, ccells,
                                        strategy=cfg.strategy,
                                        block=cfg.block)
        order = compact_order(cmsk, cap_u)
        pts, msk = cpts[order], cmsk[order]
        pparts, pcells = cparts[order], ccells[order]

    wire = _root_broadcast(_pack_wire(pts, msk, pparts, pcells), axis_name)
    pts, msk = wire[:, :d], wire[:, d] > 0.5
    order = canonical_order(pts, msk)
    final = compact(pts[order], msk[order], cfg.capacity)
    final = SkyBuffer(final.points, final.mask, final.count,
                      final.overflow | overflow)
    return final, {"union_size": union_size}


def merge_stage(sky: SkyBuffer, meta, cfg: SkyConfig, *,
                part_idx_local=None, cells_local=None, gather=None,
                axis_name=None, axis_size=None):
    if gather is None:
        gather = lambda x: x
    p_local, local_cap, d = sky.points.shape
    if part_idx_local is None:
        part_idx_local = meta["part_idx"]
    if cells_local is None:
        cells_local = meta["cells"]

    mode = resolve_merge(cfg, axis_size=axis_size,
                         p_total=p_local * (axis_size or 1),
                         local_cap=local_cap, d=d)
    # tree mode needs a workers axis to permute over; mesh-free contexts
    # (single device, the windowed merge-on-read, the engine vmap path)
    # run the identical flat math — the merge mode only changes the
    # collective schedule, never the result bits
    if mode == "tree" and axis_name is not None:
        return _tree_merge(sky, cfg, part_idx_local=part_idx_local,
                           cells_local=cells_local, axis_name=axis_name,
                           axis_size=axis_size)

    u_pts = gather(sky.points)        # (p, C_loc, d)
    u_mask = gather(sky.mask)
    u_parts = gather(part_idx_local)  # (p,)
    union_size = jnp.sum(u_mask)

    if not cfg.noseq:
        flat = u_pts.reshape(-1, d)
        fmask = u_mask.reshape(-1)
        # compact the union first: the final pass must scan |u| tuples,
        # not p x capacity padded rows (models "only the local skylines
        # are communicated", paper Alg. 2 line 4)
        cap_u = min(flat.shape[0], max(cfg.capacity, 1))
        u_compact = compact(flat, fmask, cap_u)
        # the final sequential pass reuses the same one-call fused-sweep
        # entry as the local phase (block_sfs is its single-partition
        # wrapper)
        final = block_sfs(u_compact.points, u_compact.mask,
                          capacity=cfg.capacity, block=cfg.block,
                          impl=cfg.impl, wtile=cfg.wtile)
        # canonicalize: block-SFS emits members in score order but breaks
        # score ties by its input (partition-gather) order; the total
        # lexicographic tie-break makes the merge output independent of
        # how the data reached it, which the incremental path relies on
        # for bitwise chunking-invariance
        order = canonical_order(final.points, final.mask)
        overflow = final.overflow | u_compact.overflow
        final = SkyBuffer(final.points[order], final.mask[order],
                          final.count, overflow)
        return final, {"union_size": union_size}

    refs = u_pts.reshape(-1, d)
    refmask = u_mask.reshape(-1)
    ref_parts = jnp.repeat(u_parts, local_cap)
    ref_cells = jnp.repeat(gather(cells_local), local_cap, axis=0)
    # compact the gathered union (valid rows first, truncated) through
    # the same shared `compact` helper as the sequential branch, so each
    # worker tests against |u| refs, not p x capacity padded rows — and
    # the union-truncation overflow accounting is identical in both
    # branches
    cap_u = min(refs.shape[0], max(cfg.capacity, 1))
    u_compact = compact(refs, refmask, cap_u)
    order = compact_order(refmask, cap_u)
    refs, refmask = u_compact.points, u_compact.mask
    ref_parts = ref_parts[order]
    ref_cells = ref_cells[order]

    dom_impl = resolve_spec(cfg.impl).dominance

    def filter_one(u_i, m_i, own_part, own_cell):
        pd = noseq.pd_row_mask(cfg.strategy, own_part, ref_parts,
                               own_cell, ref_cells)
        return noseq.relative_skyline_mask(u_i, m_i, refs, refmask, pd,
                                           impl=dom_impl)

    final_mask_local = jax.vmap(filter_one)(
        sky.points, sky.mask, part_idx_local, cells_local)
    # assemble a single replicated result buffer, in canonical order
    # (total: score, then lexicographic coordinates) before compaction,
    # so the merge output is independent of the partition layout — the
    # same order the sequential merge emits, which the incremental path
    # (repro.core.incremental) relies on for bitwise chunking-invariance
    all_pts = gather(sky.points).reshape(-1, d)
    all_mask = gather(final_mask_local).reshape(-1)
    order = canonical_order(all_pts, all_mask)
    final = compact(all_pts[order], all_mask[order], cfg.capacity)
    final = SkyBuffer(final.points, final.mask, final.count,
                      final.overflow | u_compact.overflow)
    return final, {"union_size": union_size}


# --------------------------------------------------------------------------
# Public entry point: one jitted program for partition + local + merge
# --------------------------------------------------------------------------

# Python-side effect executed once per trace of the fused pipeline — a
# traced-callback counter. jit's cache makes repeated same-shape calls
# skip tracing entirely, so tests can assert "compiled once" by reading
# trace_count() around a loop of calls.
_TRACE_EVENTS: collections.Counter[str] = collections.Counter()


def trace_count(label: str = "fused") -> int:
    """How many times the fused pipeline has been (re)traced."""
    return _TRACE_EVENTS[label]


def _local_merge(bufs, bmask, key, part_idx, cells, *, cfg: SkyConfig,
                 meta, gather, axis_name=None, axis_size=None):
    """One query's phase 1 + phase 2 on this worker's partitions.

    Shared by every execution mode: single-device (gather = identity),
    1-D workers shard_map, and the 2-D queries x workers program (where
    this body runs under an outer vmap over the local query shard).
    ``axis_name``/``axis_size`` name the workers mesh axis when running
    under shard_map — the tree merge permutes over it; without an axis
    the merge runs the flat schedule (same bits)."""
    sky, s2 = local_stage(bufs, bmask, cfg, key=key, gather=gather)
    final, s3 = merge_stage(sky, meta, cfg, part_idx_local=part_idx,
                            cells_local=cells, gather=gather,
                            axis_name=axis_name, axis_size=axis_size)
    return final, dict(s2, **s3)


def _body_stat_keys(cfg: SkyConfig) -> tuple[str, ...]:
    """Stats emitted by `_local_merge` (shard_map out_specs need them)."""
    return ("local_sizes", "local_overflow", "union_size",
            *(("rep_filter_dropped",) if cfg.rep_filter else ()))


def _fused(pts, mask, key, *, cfg: SkyConfig, mesh, axis_name: str):
    """The whole pipeline as one traceable function (no host sync).

    A thin wrapper over `repro.core.incremental`: one-shot execution is
    "insert everything into an empty SkylineState" — the fresh-state
    insert statically skips the pre-filter/evict passes, so the body is
    exactly the partition+local+merge program, and the returned buffer is
    the state's packed antichain (already in canonical SFS score order).
    """
    from repro.core import incremental
    _TRACE_EVENTS["fused"] += 1
    state, stats = incremental._insert(None, pts, mask, key, cfg=cfg,
                                       mesh=mesh, axis_name=axis_name)
    return (SkyBuffer(state.points, state.mask, state.count,
                      state.overflow), stats)


def _fused_batch(pts, mask, keys, *, cfg: SkyConfig, mesh,
                 q_axis: str, w_axis: str):
    """A (Q, N, d) query batch as one 2-D (queries x workers) program.

    The query batch is sharded over `q_axis` while each query's routed
    partition buckets are sharded over `w_axis`; within a query shard the
    local+merge body is vmapped over the queries it holds, and
    collectives (all_gather of representatives / local skylines) run over
    `w_axis` only — each query merges against its own partitions. This is
    the engine's large-N regime: vmap-over-queries alone leaves the
    workers mesh idle, tuple-sharding alone leaves query parallelism on
    the table; the 2-D mesh buys both at once.

    Like `_fused`, a thin wrapper over the batched fresh-state insert of
    `repro.core.incremental` (Q empty states fed in one dispatch).
    """
    from repro.core import incremental
    _TRACE_EVENTS["fused_batch"] += 1
    state, stats = incremental._insert_batch(None, pts, mask, keys,
                                             cfg=cfg, mesh=mesh,
                                             q_axis=q_axis, w_axis=w_axis)
    return (SkyBuffer(state.points, state.mask, state.count,
                      state.overflow), stats)


@functools.lru_cache(maxsize=None)
def fused_skyline_fn(cfg: SkyConfig, mesh: jax.sharding.Mesh | None = None,
                     axis_name: str = "workers"):
    """The jitted fused pipeline for a given config/mesh.

    Signature of the returned callable: ``(pts, mask, key) -> (SkyBuffer,
    stats)`` with mask/key required (pass ``jnp.ones(n, bool)`` /
    ``jax.random.PRNGKey(0)`` for the defaults). Cached so every caller
    with the same (cfg, mesh, axis_name) shares one jit cache — repeated
    same-shape queries compile exactly once.
    """
    return jax.jit(functools.partial(_fused, cfg=cfg, mesh=mesh,
                                     axis_name=axis_name))


@functools.lru_cache(maxsize=None)
def fused_skyline_batch_fn(cfg: SkyConfig,
                           mesh: jax.sharding.Mesh | None = None,
                           q_axis: str = "queries",
                           w_axis: str = "workers"):
    """The jitted batched pipeline: ``(pts (Q, N, d), mask (Q, N),
    keys (Q, ...)) -> (SkyBuffer, stats)`` with a leading Q axis on every
    output leaf.

    Without a mesh this is plain vmap-over-queries of the fused program
    (the engine's small-query path). With a 2-D mesh carrying `q_axis`
    and `w_axis` it is the queries x workers sharded program: Q must be a
    multiple of the `q_axis` size and cfg's partition count a multiple of
    the `w_axis` size. Both variants are bit-for-bit equivalent — the
    sharded program runs the identical comparison/selection math, only
    placed across devices.
    """
    if mesh is None:
        return jax.jit(jax.vmap(functools.partial(
            _fused, cfg=cfg, mesh=None, axis_name=w_axis)))
    return jax.jit(functools.partial(_fused_batch, cfg=cfg, mesh=mesh,
                                     q_axis=q_axis, w_axis=w_axis))


def parallel_skyline(pts: jnp.ndarray, mask: jnp.ndarray | None = None, *,
                     cfg: SkyConfig = SkyConfig(),
                     key: jax.Array | None = None,
                     mesh: jax.sharding.Mesh | None = None,
                     axis_name: str = "workers"):
    """Compute SKY(pts) with the parallel pattern of the paper.

    Returns (SkyBuffer, stats). With `mesh`, partitions are sharded over
    `axis_name` and executed under shard_map; p must be a multiple of the
    mesh axis size. partition -> local -> merge execute as a single
    device-resident program: no intermediate device_put, and the stats
    pytree is made of device arrays (host sync only when read).
    """
    n = pts.shape[0]
    if mask is None:
        mask = jnp.ones((n,), jnp.bool_)
    if key is None:
        key = jax.random.PRNGKey(0)
    return fused_skyline_fn(cfg, mesh, axis_name)(pts, mask, key)
