"""Synthetic dataset generators (Börzsönyi et al. conventions, paper §5)
plus real-dataset loading with a documented surrogate fallback.

All generators emit points in [0, 1]^d where smaller is better.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["generate", "uniform", "correlated", "anticorrelated",
           "load_real", "DISTRIBUTIONS"]


def uniform(key: jax.Array, n: int, d: int) -> jnp.ndarray:
    """Independent U[0,1] per attribute."""
    return jax.random.uniform(key, (n, d), jnp.float32)


def correlated(key: jax.Array, n: int, d: int,
               spread: float = 0.15) -> jnp.ndarray:
    """Points clustered around the main diagonal: a common base value per
    tuple plus small independent jitter, reflected into [0, 1]."""
    kb, kj = jax.random.split(key)
    base = jax.random.uniform(kb, (n, 1), jnp.float32)
    jit = jax.random.normal(kj, (n, d), jnp.float32) * spread
    x = base + jit
    # reflect out-of-range values back inside [0,1] (avoids boundary atoms
    # that plain clipping would create)
    x = jnp.abs(x)
    x = 1.0 - jnp.abs(1.0 - x)
    return jnp.clip(x, 0.0, 1.0)


def anticorrelated(key: jax.Array, n: int, d: int,
                   spread: float = 0.15) -> jnp.ndarray:
    """Points near the anti-diagonal hyperplane sum(x) ~ d/2: good in one
    attribute implies bad in others — the hardest case for skylines
    (paper §5: largest skylines, most dominance tests). The per-tuple
    plane offset is kept tight (std 0.05) so tuples are mutually hard to
    dominate, as in the Börzsönyi generator."""
    kb, kj = jax.random.split(key)
    base = 0.5 + 0.05 * jax.random.normal(kb, (n, 1), jnp.float32)
    jit = jax.random.uniform(kj, (n, d), jnp.float32, -0.5, 0.5)
    # zero-sum jitter spreads each tuple ALONG its hyperplane sum = d*base
    jit = (jit - jnp.mean(jit, axis=-1, keepdims=True)) * 0.9
    x = base + jit
    x = jnp.abs(x)
    x = 1.0 - jnp.abs(1.0 - x)
    return jnp.clip(x, 0.0, 1.0)


DISTRIBUTIONS = {
    "uniform": uniform,
    "correlated": correlated,
    "anticorrelated": anticorrelated,
}


def generate(dist: str, key: jax.Array, n: int, d: int) -> jnp.ndarray:
    try:
        fn = DISTRIBUTIONS[dist]
    except KeyError:
        raise ValueError(
            f"unknown distribution {dist!r}; one of {list(DISTRIBUTIONS)}")
    return fn(key, n, d)


# ---------------------------------------------------------------------------
# Real datasets (paper §5: HOU = household electricity, 2,049,280 x 7;
# RES = Zillow housing, 3,569,678 x 7). The raw files are not shipped; if a
# CSV is present at $REPRO_DATA_DIR/<name>.csv we load it, otherwise we
# synthesize a documented surrogate with similar gross statistics (heavy
# skew + mixed correlation structure across attribute pairs).
# ---------------------------------------------------------------------------

def _surrogate(name: str, n: int, d: int) -> np.ndarray:
    rng = np.random.default_rng(abs(hash(name)) % (2 ** 31))
    # mixture of correlated groups with log-normal marginals (utility-meter
    # -like skew), min-max normalized to [0,1]
    g = rng.integers(0, 3, size=d)
    latent = rng.lognormal(mean=0.0, sigma=0.6, size=(n, 3))
    noise = rng.lognormal(mean=0.0, sigma=0.4, size=(n, d))
    x = latent[:, g] * noise
    x = (x - x.min(0)) / (x.max(0) - x.min(0) + 1e-9)
    return x.astype(np.float32)


def load_real(name: str, n: int | None = None, d: int = 7) -> jnp.ndarray:
    """Load HOU/RES if available, else a synthetic surrogate (documented in
    DESIGN.md §8 scale note)."""
    name = name.lower()
    assert name in ("hou", "res"), name
    path = os.path.join(os.environ.get("REPRO_DATA_DIR", "/root/data"),
                        f"{name}.csv")
    if os.path.exists(path):
        arr = np.loadtxt(path, delimiter=",", dtype=np.float32)
        arr = arr[:, :d]
        arr = (arr - arr.min(0)) / (arr.max(0) - arr.min(0) + 1e-9)
    else:
        default_n = {"hou": 2_049_280, "res": 3_569_678}[name]
        arr = _surrogate(name, n or min(default_n, 1_000_000), d)
    if n is not None:
        arr = arr[:n]
    return jnp.asarray(arr)
