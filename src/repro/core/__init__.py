from repro.core.api import (SkyBuffer, SkyConfig, SkylineState, finalize,
                            init_state, insert_chunk, parallel_skyline,
                            skyline, skyline_mask_exact)
from repro.core.sfs import block_sfs, compact, naive_skyline_mask, skyline_mask

__all__ = [
    "SkyBuffer", "SkyConfig", "SkylineState", "parallel_skyline", "skyline",
    "skyline_mask_exact", "init_state", "insert_chunk", "finalize",
    "block_sfs", "compact", "naive_skyline_mask", "skyline_mask",
]
