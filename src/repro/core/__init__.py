from repro.core.api import (SkyBuffer, SkyConfig, parallel_skyline, skyline,
                            skyline_mask_exact)
from repro.core.sfs import block_sfs, compact, naive_skyline_mask, skyline_mask

__all__ = [
    "SkyBuffer", "SkyConfig", "parallel_skyline", "skyline",
    "skyline_mask_exact", "block_sfs", "compact", "naive_skyline_mask",
    "skyline_mask",
]
