"""Skyline algorithms: naive oracle and block-SFS (paper Algorithm 1,
adapted to TPU-style blocked execution — DESIGN.md §3 change (1)).

The local phase is ONE call: :func:`local_skyline_batch` sorts a batch of
partitions by a strictly monotone score (topological order w.r.t.
dominance) and hands the whole batch to the fused SFS sweep
(:func:`repro.kernels.sfs.sfs_sweep`) — a single dispatch that carries
each partition's window buffer and count through the entire scan, with
the in-block lower-triangular self-test fused in.  The backend layer
(repro.kernels.backend) picks the sweep implementation from the ``impl``
string: the compiled Pallas grid on TPU, the blocked single-dispatch jnp
sweep elsewhere, interpret mode for CPU validation of the kernel body,
and the legacy per-pair reference for tests/benchmarks.  All of them are
bit-for-bit equivalent (tests/test_sfs_kernel.py).

block_sfs keeps SFS's O(N * |SKY|) work profile and its exactness
argument: transitivity makes the blocked formulation exact — if the only
in-block dominator of t is itself dominated by a window tuple w, then w
dominates t too, so t is still eliminated by the window test.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.dominance import (SENTINEL, apply_sentinel, dominated_mask,
                                  monotone_score)
from repro.kernels.backend import resolve_spec
from repro.kernels.sfs import sfs_sweep

__all__ = ["SkyBuffer", "naive_skyline_mask", "skyline_mask", "block_sfs",
           "local_skyline_batch", "compact", "compact_order"]


class SkyBuffer(NamedTuple):
    """Fixed-capacity masked skyline buffer (static shapes for JAX)."""
    points: jnp.ndarray    # (C, d) packed members (leading axes allowed)
    mask: jnp.ndarray      # (C,) bool
    count: jnp.ndarray     # () int32 — true skyline size (may exceed C)
    overflow: jnp.ndarray  # () bool — True iff count > C


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def naive_skyline_mask(pts: jnp.ndarray, mask: jnp.ndarray | None = None,
                       ) -> jnp.ndarray:
    """O(N^2) full-matrix oracle; returns membership mask in input order."""
    if mask is None:
        mask = jnp.ones(pts.shape[0], jnp.bool_)
    from repro.kernels.dominance import dominated_mask_ref
    dom = dominated_mask_ref(pts, pts, mask)
    return mask & ~dom


def skyline_mask(pts: jnp.ndarray, mask: jnp.ndarray | None = None, *,
                 impl: str = "auto") -> jnp.ndarray:
    """Blocked O(N^2) skyline membership mask (memory-bounded)."""
    if mask is None:
        mask = jnp.ones(pts.shape[0], jnp.bool_)
    dom = dominated_mask(pts, pts, mask,
                         impl=resolve_spec(impl).dominance)
    return mask & ~dom


def local_skyline_batch(pts: jnp.ndarray, mask: jnp.ndarray | None = None,
                        *, capacity: int, block: int = 256,
                        impl: str = "auto", wtile: int = 0) -> SkyBuffer:
    """Blocked Sort-Filter-Skyline of a (P, N, d) partition batch in one
    fused-sweep dispatch.

    Every leaf of the returned :class:`SkyBuffer` carries a leading P
    axis.  Exact per partition whenever |SKY| <= capacity (the overflow
    flag reports violations; extra tuples are dropped, never spurious
    ones added — the result is then a subset of the skyline).

    ``wtile`` is the sweep's window-tile width (0 = whole window per
    candidate block): tiling bounds the kernel's resident comparison
    footprint at O(wtile x block) instead of O(capacity x block) without
    changing a single output bit — see `repro.kernels.sfs`.

    Precondition (repo-wide SENTINEL convention, see repro.core.
    dominance): valid data coordinates stay below ``SENTINEL`` — the
    sweeps rely on sentinel-filled rows being inert in dominance tests
    instead of carrying runtime validity masks.
    """
    if pts.ndim != 3:
        raise ValueError(f"expected a (P, N, d) batch, got {pts.shape}")
    p, n, d = pts.shape
    if mask is None:
        mask = jnp.ones((p, n), jnp.bool_)
    block = min(block, max(n, 1))
    spec = resolve_spec(impl)

    # Sort-Filter: presort every partition by the strictly monotone score
    # (dominators sort strictly earlier), sentinel-fill invalid rows, and
    # block-pad — identical bytes reach every sweep implementation.
    score = monotone_score(pts, mask)
    order = jnp.argsort(score, axis=-1)
    mask_s = jnp.take_along_axis(mask, order, 1)
    pts_s = apply_sentinel(jnp.take_along_axis(pts, order[..., None], 1),
                           mask_s)

    npad = _ceil_to(max(n, 1), block)
    pts_p = jnp.full((p, npad, d), SENTINEL, pts.dtype)
    pts_p = pts_p.at[:, :n].set(pts_s)
    mask_p = jnp.zeros((p, npad), jnp.bool_).at[:, :n].set(mask_s)

    wcap = _ceil_to(capacity, block)
    window, wmask, count = sfs_sweep(pts_p, mask_p, block=block, wcap=wcap,
                                     sentinel=float(SENTINEL),
                                     wtile=wtile, spec=spec)
    return SkyBuffer(window, wmask, count, count > capacity)


def block_sfs(pts: jnp.ndarray, mask: jnp.ndarray | None = None, *,
              capacity: int, block: int = 256, impl: str = "auto",
              wtile: int = 0) -> SkyBuffer:
    """Blocked Sort-Filter-Skyline of ONE point set: a thin wrapper over
    the batched fused-sweep entry (:func:`local_skyline_batch`) with a
    single partition.  Exact whenever |SKY| <= capacity (overflow flag
    reports violations; the result is then a subset of the skyline)."""
    buf = local_skyline_batch(
        pts[None], None if mask is None else mask[None],
        capacity=capacity, block=block, impl=impl, wtile=wtile)
    return SkyBuffer(buf.points[0], buf.mask[0], buf.count[0],
                     buf.overflow[0])


def compact_order(mask: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """The row order `compact` gathers by: stable valid-rows-first,
    truncated to ``capacity``.  Exposed so callers carrying side columns
    (partition ids, grid cells) can reorder them identically and share
    `compact`'s overflow accounting."""
    return jnp.argsort(jnp.logical_not(mask))[:capacity]


def compact(pts: jnp.ndarray, mask: jnp.ndarray, capacity: int) -> SkyBuffer:
    """Stable-move valid rows to the front; truncate to capacity."""
    order = compact_order(mask, capacity)
    mask_c = mask[order]
    pts_c = apply_sentinel(pts[order], mask_c)
    count = jnp.sum(mask).astype(jnp.int32)
    return SkyBuffer(pts_c, mask_c, count, count > capacity)
