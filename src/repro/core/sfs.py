"""Skyline algorithms: naive oracle and block-SFS (paper Algorithm 1,
adapted to TPU-style blocked execution — DESIGN.md §3 change (1)).

block_sfs keeps SFS's O(N * |SKY|) work profile: data is presorted by a
strictly monotone score (topological order w.r.t. dominance), then scanned
in blocks. Each block is tested against (a) the *active* prefix of the
window buffer — a dynamic-bound fori_loop over window blocks, so work
scales with the running skyline size, not the window capacity — and (b)
itself in lower-triangular mode. Survivors are appended to the window.

Transitivity makes the blocked formulation exact: if the only in-block
dominator of t is itself dominated by a window tuple w, then w dominates t
too, so t is still eliminated by the window test.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dominance import (SENTINEL, apply_sentinel, dominated_mask,
                                  monotone_score)

__all__ = ["SkyBuffer", "naive_skyline_mask", "skyline_mask", "block_sfs",
           "compact"]


class SkyBuffer(NamedTuple):
    """Fixed-capacity masked skyline buffer (static shapes for JAX)."""
    points: jnp.ndarray    # (C, d)
    mask: jnp.ndarray      # (C,) bool
    count: jnp.ndarray     # () int32 — true skyline size (may exceed C)
    overflow: jnp.ndarray  # () bool — True iff count > C


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def naive_skyline_mask(pts: jnp.ndarray, mask: jnp.ndarray | None = None,
                       ) -> jnp.ndarray:
    """O(N^2) full-matrix oracle; returns membership mask in input order."""
    if mask is None:
        mask = jnp.ones(pts.shape[0], jnp.bool_)
    from repro.kernels.dominance import dominated_mask_ref
    dom = dominated_mask_ref(pts, pts, mask)
    return mask & ~dom


def skyline_mask(pts: jnp.ndarray, mask: jnp.ndarray | None = None, *,
                 impl: str = "auto") -> jnp.ndarray:
    """Blocked O(N^2) skyline membership mask (memory-bounded)."""
    if mask is None:
        mask = jnp.ones(pts.shape[0], jnp.bool_)
    dom = dominated_mask(pts, pts, mask, impl=impl)
    return mask & ~dom


def block_sfs(pts: jnp.ndarray, mask: jnp.ndarray | None = None, *,
              capacity: int, block: int = 256, impl: str = "auto",
              ) -> SkyBuffer:
    """Blocked Sort-Filter-Skyline. Exact whenever |SKY| <= capacity
    (overflow flag reports violations; extra tuples are dropped, never
    spurious ones added — the result is then a subset of the skyline)."""
    n, d = pts.shape
    if mask is None:
        mask = jnp.ones(n, jnp.bool_)
    block = min(block, max(n, 1))

    score = monotone_score(pts, mask)
    order = jnp.argsort(score)
    pts_s = apply_sentinel(pts[order], mask[order])
    mask_s = mask[order]

    npad = _ceil_to(max(n, 1), block)
    pts_p = jnp.full((npad, d), SENTINEL, pts.dtype).at[:n].set(pts_s)
    mask_p = jnp.zeros((npad,), jnp.bool_).at[:n].set(mask_s)
    nb = npad // block

    wcap = _ceil_to(capacity, block)
    window0 = jnp.full((wcap, d), SENTINEL, pts.dtype)
    wmask0 = jnp.zeros((wcap,), jnp.bool_)

    if nb == 1:
        # Single-block fast path (small inputs, the serving regime): the
        # window is empty, so the lower-triangular self-test alone decides
        # membership — no blocked loop, much shallower op graph. Exact for
        # the same transitivity argument as the general case.
        domin = dominated_mask(pts_p, pts_p, mask_p, lower_tri=True,
                               impl=impl)
        keep = mask_p & ~domin
        pos = jnp.cumsum(keep) - 1
        dest = jnp.where(keep & (pos < wcap), pos, wcap)
        window = window0.at[dest].set(pts_p, mode="drop")
        wmask = wmask0.at[dest].set(True, mode="drop")
        nk = jnp.sum(keep).astype(jnp.int32)
        return SkyBuffer(window, wmask, nk, nk > capacity)

    def body(b, carry):
        window, wmask, wcount, overflow = carry
        x = jax.lax.dynamic_slice(pts_p, (b * block, 0), (block, d))
        xm = jax.lax.dynamic_slice(mask_p, (b * block,), (block,))

        # (a) dominated by the active window prefix (dynamic bound)
        nwb = jnp.minimum((wcount + block - 1) // block, wcap // block)

        def wbody(wb, acc):
            wblk = jax.lax.dynamic_slice(window, (wb * block, 0), (block, d))
            wm = jax.lax.dynamic_slice(wmask, (wb * block,), (block,))
            return acc | dominated_mask(x, wblk, wm, impl=impl)

        domw = jax.lax.fori_loop(0, nwb, wbody,
                                 jnp.zeros((block,), jnp.bool_))
        # (b) dominated within the block by an earlier (smaller-score) row
        domin = dominated_mask(x, x, xm, lower_tri=True, impl=impl)

        keep = xm & ~domw & ~domin
        pos = wcount + jnp.cumsum(keep) - 1
        dest = jnp.where(keep & (pos < wcap), pos, wcap)
        window = window.at[dest].set(x, mode="drop")
        wmask = wmask.at[dest].set(True, mode="drop")
        nk = jnp.sum(keep)
        overflow = overflow | (wcount + nk > capacity)
        return window, wmask, wcount + nk, overflow

    window, wmask, wcount, overflow = jax.lax.fori_loop(
        0, nb, body, (window0, wmask0, jnp.int32(0), jnp.bool_(False)))
    return SkyBuffer(window, wmask, wcount, overflow)


def compact(pts: jnp.ndarray, mask: jnp.ndarray, capacity: int) -> SkyBuffer:
    """Stable-move valid rows to the front; truncate to capacity."""
    order = jnp.argsort(jnp.logical_not(mask))  # stable: valid rows first
    pts_c = apply_sentinel(pts[order][:capacity], mask[order][:capacity])
    mask_c = mask[order][:capacity]
    count = jnp.sum(mask).astype(jnp.int32)
    return SkyBuffer(pts_c, mask_c, count, count > capacity)
