"""Device-resident incremental skyline maintenance (`SkylineState`).

The paper's block-structured sequential filtering — local skylines merged
against a retained candidate buffer — is naturally incremental: the
retained buffer IS a running skyline, and an arriving chunk only has to be
(a) filtered against it, (b) reduced to its own skyline, and (c) merged
back, evicting members the new tuples dominate. This module makes that
buffer a first-class, device-resident pytree and the single currency of
every execution path:

  ``SkylineState``  — packed skyline buffer + validity mask + running
                      stats (count / overflow / tuples seen / chunks fed),
                      optionally carrying a leading Q axis so Q live
                      skylines are maintained in ONE dispatch.
  ``init_state``    — empty state (all-masked buffer, zeroed stats).
  ``insert_chunk``  — filter an arriving chunk against the live skyline,
                      compute the survivors' skyline with the fused
                      partition+local+merge pipeline, evict newly
                      dominated members, and merge — one compaction pass,
                      one jitted program, no host round-trip.
  ``finalize``      — canonicalize the state into a ``SkyBuffer``
                      (SFS score order, compacted) bit-for-bit equal to
                      the one-shot ``parallel_skyline`` answer for the
                      same data, regardless of how it was chunked.

The one-shot entry points (`repro.core.parallel.fused_skyline_fn` /
`fused_skyline_batch_fn`) are thin wrappers over this module: "init from
an empty state + feed everything" — statically specialised so the empty
pre-filter/evict passes fold away to exactly the old pipeline.

Exactness of the incremental step (all by dominance transitivity):

  * pre-filter: a chunk tuple dominated by a live member can only lose
    its dominator to a *new* tuple that dominates the dominator — and
    hence the chunk tuple too; dropping it early is safe.
  * eviction: any chunk tuple dominating a live member is either itself a
    surviving new member or is dominated by one (never by a live member —
    the live buffer is an antichain), so testing the live buffer against
    the chunk *survivors* alone is complete.

Together these keep the invariant: after every insert, the state holds
exactly SKY(all valid tuples fed so far).

Batched inserts shard over the engine's 2-D ``(queries, workers)`` mesh:
the Q states and chunks over ``queries``, each chunk's partition buckets
over ``workers`` — same placement as the one-shot batch program.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import parallel as par
from repro.core.dominance import (SENTINEL, apply_sentinel, canonical_order,
                                  dominated_mask)
from repro.core.parallel import SkyConfig
from repro.core.sfs import SkyBuffer, compact
from repro.kernels.backend import resolve_spec

__all__ = ["SkylineState", "state_capacity", "init_state", "insert_chunk",
           "finalize", "insert_chunk_fn", "insert_chunk_batch_fn",
           "finalize_fn"]


class SkylineState(NamedTuple):
    """Fixed-capacity running skyline, resident on device between chunks.

    Leaves are either unbatched (one live skyline) or carry a leading Q
    axis (Q live skylines maintained together). The buffer is always an
    antichain holding exactly the skyline of every valid tuple fed so far
    (whenever no capacity overflow occurred — ``overflow`` reports it).
    """
    points: jnp.ndarray    # (C, d) or (Q, C, d) packed members
    mask: jnp.ndarray      # (C,) or (Q, C) bool validity
    count: jnp.ndarray     # () or (Q,) int32 — live skyline size
    overflow: jnp.ndarray  # () or (Q,) bool — capacity ever exceeded
    seen: jnp.ndarray      # () or (Q,) int32 — valid tuples fed so far
    chunks: jnp.ndarray    # () or (Q,) int32 — insert_chunk calls absorbed


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def state_capacity(cfg: SkyConfig) -> int:
    """Row count of the state buffer: the final-merge window size of the
    fused pipeline (capacity rounded up to the dominance block), so the
    one-shot answer drops into a state with no reshaping."""
    return _ceil_to(max(cfg.capacity, 1), cfg.block)


def init_state(cfg: SkyConfig, d: int, *, dtype=jnp.float32,
               q: int | None = None) -> SkylineState:
    """Empty state for ``d``-attribute tuples; ``q`` adds a leading batch
    axis (q live skylines). All leaves are device arrays from the start —
    the state never lives on the host."""
    lead = () if q is None else (q,)
    c = state_capacity(cfg)
    return SkylineState(
        points=jnp.full(lead + (c, d), SENTINEL, dtype),
        mask=jnp.zeros(lead + (c,), jnp.bool_),
        count=jnp.zeros(lead, jnp.int32),
        overflow=jnp.zeros(lead, jnp.bool_),
        seen=jnp.zeros(lead, jnp.int32),
        chunks=jnp.zeros(lead, jnp.int32))


def _fit_rows(points: jnp.ndarray, mask: jnp.ndarray, rows: int):
    """Pad (sentinel/False) or truncate the row axis to ``rows``.

    The merge window of the fused pipeline is capacity rounded to the
    *effective* block (block is clipped to the union size for tiny
    unions), so its row count can differ from ``state_capacity``;
    truncation is safe because members never exceed the compacted union
    size, which is below the state capacity whenever shapes diverge."""
    c = points.shape[-2]
    if c == rows:
        return points, mask
    if c > rows:
        return points[..., :rows, :], mask[..., :rows]
    pw_p = [(0, 0)] * points.ndim
    pw_p[-2] = (0, rows - c)
    pw_m = [(0, 0)] * mask.ndim
    pw_m[-1] = (0, rows - c)
    return (jnp.pad(points, pw_p, constant_values=SENTINEL),
            jnp.pad(mask, pw_m, constant_values=False))


# --------------------------------------------------------------------------
# The chunk pipeline: one query's partition+local+merge (the former
# parallel._fused / _fused_batch bodies, now the skyline reduction every
# insert — and every one-shot call — runs on its input)
# --------------------------------------------------------------------------

def _chunk_skyline(pts, mask, key, *, cfg: SkyConfig, mesh, axis_name: str):
    """SKY(chunk) via partition -> local -> merge, optionally shard_mapped
    over a 1-D ``workers`` mesh (no host sync; see repro.core.parallel)."""
    buckets, meta, stats = par.partition_stage(pts, mask, cfg, key)
    p = meta["p"]

    if mesh is None:
        final, s2 = par._local_merge(
            buckets.points, buckets.mask, jax.random.fold_in(key, 1),
            meta["part_idx"], meta["cells"], cfg=cfg, meta=meta,
            gather=lambda x: x)
    else:
        nworkers = mesh.shape[axis_name]
        if p % nworkers != 0:
            raise ValueError(f"p={p} not divisible by {nworkers} workers")
        # Hand the routed buckets to the workers axis *inside* the same
        # program — a sharding constraint, not a host transfer.
        spec = NamedSharding(mesh, P(axis_name))
        bufs = jax.lax.with_sharding_constraint(buckets.points, spec)
        bmask = jax.lax.with_sharding_constraint(buckets.mask, spec)
        part_idx = jax.lax.with_sharding_constraint(meta["part_idx"], spec)
        cells = jax.lax.with_sharding_constraint(meta["cells"], spec)
        local_key = jax.random.fold_in(key, 1)

        def body(bufs, bmask, part_idx, cells, local_key):
            gather = lambda x: jax.lax.all_gather(
                x, axis_name, axis=0, tiled=True)
            final, s2 = par._local_merge(bufs, bmask, local_key, part_idx,
                                         cells, cfg=cfg, meta=meta,
                                         gather=gather, axis_name=axis_name,
                                         axis_size=nworkers)
            # gather per-partition stats, keep scalars replicated
            s2["local_sizes"] = gather(s2["local_sizes"])
            return final, s2

        final, s2 = shard_map(
            body, mesh=mesh,
            in_specs=(P(axis_name), P(axis_name), P(axis_name),
                      P(axis_name), P()),
            out_specs=(SkyBuffer(P(), P(), P(), P()),
                       {k: P() for k in par._body_stat_keys(cfg)}),
            check_vma=False)(bufs, bmask, part_idx, cells, local_key)

    stats.update(s2)
    overflow = (buckets.overflow | stats.get("local_overflow", False)
                | final.overflow)
    final = SkyBuffer(final.points, final.mask, final.count, overflow)
    return final, stats


def _chunk_skyline_batch(pts, mask, keys, *, cfg: SkyConfig, mesh,
                         q_axis: str, w_axis: str):
    """A (Q, N, d) chunk batch as one 2-D (queries x workers) program.

    The query batch is sharded over `q_axis` while each query's routed
    partition buckets are sharded over `w_axis`; within a query shard the
    local+merge body is vmapped over the queries it holds, and
    collectives (all_gather of representatives / local skylines) run over
    `w_axis` only — each query merges against its own partitions.
    """
    qb, _, d = pts.shape
    p, m = par.effective_parts(cfg, d)
    nq, nw = mesh.shape[q_axis], mesh.shape[w_axis]
    if p % nw != 0:
        raise ValueError(f"p={p} not divisible by {nw} workers")
    if qb % nq != 0:
        raise ValueError(f"Q={qb} not divisible by {nq} query shards")

    def part_one(pts_i, mask_i, key_i):
        buckets, _, stats = par.partition_stage(pts_i, mask_i, cfg, key_i)
        return buckets, stats

    buckets, stats = jax.vmap(part_one)(pts, mask, keys)
    # per-partition metadata is query-independent — build it once, and
    # shard it over the workers axis only (no queries dimension)
    cells = (par._grid_cells(p, m, d) if cfg.strategy == "grid"
             else jnp.zeros((p, d), jnp.int32))
    part_idx = jnp.arange(p, dtype=jnp.int32)
    meta = {"p": p, "m": m, "cells": cells, "part_idx": part_idx}

    spec_qw = NamedSharding(mesh, P(q_axis, w_axis))
    spec_w = NamedSharding(mesh, P(w_axis))
    bufs = jax.lax.with_sharding_constraint(buckets.points, spec_qw)
    bmask = jax.lax.with_sharding_constraint(buckets.mask, spec_qw)
    part_idx = jax.lax.with_sharding_constraint(part_idx, spec_w)
    cells = jax.lax.with_sharding_constraint(cells, spec_w)
    local_keys = jax.lax.with_sharding_constraint(
        jax.vmap(lambda k: jax.random.fold_in(k, 1))(keys),
        NamedSharding(mesh, P(q_axis)))

    def body(bufs, bmask, part_idx, cells, local_keys):
        gather = lambda x: jax.lax.all_gather(x, w_axis, axis=0, tiled=True)

        def one(b, bm, k):
            final, s2 = par._local_merge(b, bm, k, part_idx, cells, cfg=cfg,
                                         meta=meta, gather=gather,
                                         axis_name=w_axis, axis_size=nw)
            s2["local_sizes"] = gather(s2["local_sizes"])
            return final, s2

        return jax.vmap(one)(bufs, bmask, local_keys)

    final, s2 = shard_map(
        body, mesh=mesh,
        in_specs=(P(q_axis, w_axis), P(q_axis, w_axis), P(w_axis),
                  P(w_axis), P(q_axis)),
        out_specs=(SkyBuffer(P(q_axis), P(q_axis), P(q_axis), P(q_axis)),
                   {k: P(q_axis) for k in par._body_stat_keys(cfg)}),
        check_vma=False)(bufs, bmask, part_idx, cells, local_keys)

    stats.update(s2)
    overflow = (buckets.overflow | s2["local_overflow"] | final.overflow)
    final = SkyBuffer(final.points, final.mask, final.count, overflow)
    return final, stats


# --------------------------------------------------------------------------
# Insert: pre-filter -> chunk skyline -> evict -> one-pass compact merge
# --------------------------------------------------------------------------

def _insert(state: SkylineState | None, pts, mask, key, *, cfg: SkyConfig,
            mesh, axis_name: str):
    """One query's insert step (traceable). ``state=None`` is the
    statically-fresh path: pre-filter and eviction fold away and the body
    is exactly the one-shot fused pipeline — this is what makes
    `fused_skyline_fn` a zero-overhead wrapper.

    The row count is the *state's* (== `state_capacity` for ordinary
    states; windowed epoch sub-states may carry fewer rows — their
    retained-candidate buffers are sized to epoch fronts, not the whole
    window). A skyline outgrowing the rows sets the overflow flag."""
    c = state_capacity(cfg) if state is None else state.points.shape[-2]
    # pre-filter/evict are pairwise passes between two different point
    # sets (chunk vs live antichain): they use the backend spec's
    # dominance kernel, while the reduction inside `_chunk_skyline` goes
    # through the fused sweep
    dom_impl = resolve_spec(cfg.impl).dominance
    stats: dict[str, Any] = {}
    if state is not None:
        stats["chunk_arrivals"] = jnp.sum(mask).astype(jnp.int32)
        # pre-filter the arriving chunk against the live skyline
        mask = mask & ~dominated_mask(pts, state.points, state.mask,
                                      impl=dom_impl)
    sky, pstats = _chunk_skyline(pts, mask, key, cfg=cfg, mesh=mesh,
                                 axis_name=axis_name)
    stats.update(pstats)
    new_pts, new_mask = _fit_rows(sky.points, sky.mask, c)

    if state is None:
        nst = SkylineState(new_pts, new_mask, sky.count, sky.overflow,
                           seen=stats["n_valid"].astype(jnp.int32),
                           chunks=jnp.int32(1))
        return nst, stats

    # evict live members newly dominated by the chunk's survivors, then
    # merge both antichains with one stable compaction pass
    evict = state.mask & dominated_mask(state.points, new_pts, new_mask,
                                        impl=dom_impl)
    merged = compact(jnp.concatenate([state.points, new_pts]),
                     jnp.concatenate([state.mask & ~evict, new_mask]), c)
    overflow = (state.overflow | sky.overflow | merged.overflow
                | (merged.count > cfg.capacity) | (sky.count > c))
    nst = SkylineState(merged.points, merged.mask, merged.count, overflow,
                       seen=state.seen + stats["chunk_arrivals"],
                       chunks=state.chunks + 1)
    stats["evicted"] = jnp.sum(evict).astype(jnp.int32)
    stats["inserted"] = sky.count
    return nst, stats


def _insert_batch(state: SkylineState | None, pts, mask, keys, *,
                  cfg: SkyConfig, mesh, q_axis: str, w_axis: str):
    """Q live skylines advanced in one dispatch. Without a mesh the body
    is vmap-over-queries of `_insert`; with a 2-D mesh the states and
    chunks shard over ``q_axis`` and each chunk's partitions over
    ``w_axis`` (same placement as the one-shot batch program)."""
    if mesh is None:
        one = functools.partial(_insert, cfg=cfg, mesh=None,
                                axis_name=w_axis)
        if state is None:
            return jax.vmap(lambda p, m, k: one(None, p, m, k))(
                pts, mask, keys)
        return jax.vmap(one)(state, pts, mask, keys)

    c = state_capacity(cfg) if state is None else state.points.shape[-2]
    dom_impl = resolve_spec(cfg.impl).dominance
    spec_q = NamedSharding(mesh, P(q_axis))
    stats: dict[str, Any] = {}
    if state is not None:
        sp = jax.lax.with_sharding_constraint(state.points, spec_q)
        sm = jax.lax.with_sharding_constraint(state.mask, spec_q)
        stats["chunk_arrivals"] = jnp.sum(mask, axis=1).astype(jnp.int32)
        mask = mask & ~jax.vmap(
            lambda x, rp, rm: dominated_mask(x, rp, rm, impl=dom_impl))(
            pts, sp, sm)

    sky, pstats = _chunk_skyline_batch(pts, mask, keys, cfg=cfg, mesh=mesh,
                                       q_axis=q_axis, w_axis=w_axis)
    stats.update(pstats)
    new_pts, new_mask = _fit_rows(sky.points, sky.mask, c)
    new_pts = jax.lax.with_sharding_constraint(new_pts, spec_q)

    if state is None:
        nst = SkylineState(new_pts, new_mask, sky.count, sky.overflow,
                           seen=stats["n_valid"].astype(jnp.int32),
                           chunks=jnp.ones_like(sky.count))
        return nst, stats

    evict = state.mask & jax.vmap(
        lambda x, rp, rm: dominated_mask(x, rp, rm, impl=dom_impl))(
        sp, new_pts, new_mask)
    merged = jax.vmap(lambda p, m: compact(p, m, c))(
        jnp.concatenate([sp, new_pts], axis=1),
        jnp.concatenate([state.mask & ~evict, new_mask], axis=1))
    overflow = (state.overflow | sky.overflow | merged.overflow
                | (merged.count > cfg.capacity) | (sky.count > c))
    nst = SkylineState(merged.points, merged.mask, merged.count, overflow,
                       seen=state.seen + stats["chunk_arrivals"],
                       chunks=state.chunks + 1)
    stats["evicted"] = jnp.sum(evict, axis=1).astype(jnp.int32)
    stats["inserted"] = sky.count
    return nst, stats


def _finalize(state: SkylineState, *, cfg: SkyConfig) -> SkyBuffer:
    """Canonicalize the state: total-order sort (monotone score, then
    lexicographic coordinates — `canonical_order`) + sentinel fill. The
    state is an antichain by invariant, so no dominance tests are needed
    — and because the order is a *total* order on point values, the
    result is bit-for-bit the one-shot fused pipeline's merge output for
    the same data (both merge modes canonicalize the same way),
    regardless of arrival order or score ties."""
    order = canonical_order(state.points, state.mask)
    mask = state.mask[order]
    return SkyBuffer(apply_sentinel(state.points[order], mask), mask,
                     state.count, state.overflow)


# --------------------------------------------------------------------------
# Jitted entry points, cached per (cfg, mesh, axis names) like the fused
# pipeline — repeated same-shape chunks never retrace
# (`parallel.trace_count("insert"/"insert_batch")` observes).
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def insert_chunk_fn(cfg: SkyConfig, mesh: jax.sharding.Mesh | None = None,
                    axis_name: str = "workers"):
    """Jitted ``(state, pts, mask, key) -> (state', stats)`` for one live
    skyline. Mask/key are required (pass ``jnp.ones(n, bool)`` /
    ``jax.random.PRNGKey(0)`` for the defaults)."""

    def run(state, pts, mask, key):
        par._TRACE_EVENTS["insert"] += 1
        return _insert(state, pts, mask, key, cfg=cfg, mesh=mesh,
                       axis_name=axis_name)

    # single-owner update: the incoming state's buffers are reused for
    # state' (callers rebind `state, _ = ins(state, ...)`); cfg.donate=False
    # keeps copy semantics for A/B tests and benchmarks
    return jax.jit(run, donate_argnums=(0,)) if cfg.donate else jax.jit(run)


@functools.lru_cache(maxsize=None)
def insert_chunk_batch_fn(cfg: SkyConfig,
                          mesh: jax.sharding.Mesh | None = None,
                          q_axis: str = "queries",
                          w_axis: str = "workers"):
    """Jitted ``(state, pts (Q, N, d), mask (Q, N), keys (Q, ...)) ->
    (state', stats)`` advancing Q live skylines in one dispatch. With a
    2-D mesh, Q must be a multiple of the ``q_axis`` size and cfg's
    partition count a multiple of the ``w_axis`` size."""

    def run(state, pts, mask, keys):
        par._TRACE_EVENTS["insert_batch"] += 1
        return _insert_batch(state, pts, mask, keys, cfg=cfg, mesh=mesh,
                             q_axis=q_axis, w_axis=w_axis)

    return jax.jit(run, donate_argnums=(0,)) if cfg.donate else jax.jit(run)


@functools.lru_cache(maxsize=None)
def finalize_fn(cfg: SkyConfig, batched: bool = False):
    """Jitted ``state -> SkyBuffer`` canonical snapshot (non-destructive:
    the state stays live and can keep absorbing chunks)."""
    fn = functools.partial(_finalize, cfg=cfg)
    return jax.jit(jax.vmap(fn) if batched else fn)


def insert_chunk(state: SkylineState, pts: jnp.ndarray,
                 mask: jnp.ndarray | None = None, *, cfg: SkyConfig,
                 key: jax.Array | None = None,
                 mesh: jax.sharding.Mesh | None = None,
                 axis_name: str = "workers"):
    """Convenience wrapper over `insert_chunk_fn` with defaulted mask/key.

    Dispatches the batched program when the state carries a leading Q axis
    (pts must then be (Q, N, d) and ``axis_name`` names the workers axis
    of a 2-D mesh)."""
    batched = state.points.ndim == 3
    if mask is None:
        mask = jnp.ones(pts.shape[:-1], jnp.bool_)
    if key is None:
        key = jax.random.PRNGKey(0)
    if batched:
        q = state.points.shape[0]
        keys = key if key.ndim == 2 else jax.random.split(key, q)
        return insert_chunk_batch_fn(cfg, mesh, w_axis=axis_name)(
            state, pts, mask, keys)
    return insert_chunk_fn(cfg, mesh, axis_name)(state, pts, mask, key)


def finalize(state: SkylineState, *, cfg: SkyConfig) -> SkyBuffer:
    """Canonical `SkyBuffer` snapshot of one or Q live skylines."""
    return finalize_fn(cfg, state.points.ndim == 3)(state)
