"""Dominance primitives (paper Definitions 1 and 2).

Point sets are masked: ``(pts: (N, d) f32, mask: (N,) bool)``. Invalid rows
additionally carry the ``SENTINEL`` coordinate so that, even if a mask is
dropped by mistake, a sentinel row can never dominate a real point (defense
in depth; the masks remain authoritative).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dominance import dominated_mask as _dominated_mask
from repro.kernels.dominance import dominance_matrix_ref

__all__ = [
    "SENTINEL", "dominates", "dominance_matrix", "dominated_mask",
    "region_volume", "monotone_score", "canonical_order", "apply_sentinel",
]

# Large-but-finite: sums of up to 8 sentinels stay finite in f32? They do
# not (8 * 1.7e38 overflows) — inf from an overflowed sentinel score still
# sorts last, which is exactly what we need.
SENTINEL = jnp.float32(1.7e38)


def dominates(t: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Scalar predicate: does point t dominate point s?"""
    return jnp.all(t <= s) & jnp.any(t < s)


def dominance_matrix(refs: jnp.ndarray, cands: jnp.ndarray) -> jnp.ndarray:
    """(R, C) bool: out[j, i] = refs[j] dominates cands[i] (small inputs)."""
    return dominance_matrix_ref(refs, cands)


def dominated_mask(cands, refs, ref_mask=None, *, lower_tri=False,
                   impl="auto"):
    """Blocked kernel entry point (see kernels/dominance/ops.py)."""
    return _dominated_mask(cands, refs, ref_mask, lower_tri=lower_tri,
                           impl=impl)


def region_volume(pts: jnp.ndarray) -> jnp.ndarray:
    """Hyper-volume of the dominance region on [0,1]^d (paper §4.1):
    V(DR(t)) = prod_i (1 - t[i]). Values outside [0,1] clamp to volume 0
    contribution-wise (REGION requires normalized data, paper §4.1)."""
    return jnp.prod(jnp.clip(1.0 - pts, 0.0, 1.0), axis=-1)


def monotone_score(pts: jnp.ndarray, mask: jnp.ndarray | None = None
                   ) -> jnp.ndarray:
    """The strictly monotone scoring function used for SFS presorting
    (f = sum of attributes). Invalid rows score +inf so they sort last.
    Strict monotonicity gives the topological-order property: t < s implies
    score(t) < score(s)."""
    s = jnp.sum(pts, axis=-1)
    if mask is not None:
        s = jnp.where(mask, s, jnp.inf)
    return s


def canonical_order(pts: jnp.ndarray, mask: jnp.ndarray | None = None
                    ) -> jnp.ndarray:
    """Permutation sorting by monotone score with lexicographic
    coordinates as tie-break — a *total* order on point values, so the
    result is independent of the input permutation. Equal-score points
    can never dominate each other (t < s implies score(t) < score(s)),
    so any tie order is a valid SFS topological order; fixing it
    lexicographically is what makes canonicalized buffers bitwise
    comparable across execution paths (one-shot vs any chunking —
    repro.core.incremental relies on this). Invalid rows sort last."""
    score = monotone_score(pts, mask)
    keys = tuple(pts[:, j] for j in reversed(range(pts.shape[1])))
    return jnp.lexsort(keys + (score,))


def apply_sentinel(pts: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Overwrite invalid rows with the sentinel coordinate."""
    return jnp.where(mask[..., None], pts, SENTINEL)
