"""Serving driver: Pareto-front (skyline) request admission + batched
prefill/greedy-decode.

Admission runs through the batched `SkylineEngine`: with ``--queues Q``
the driver admits from Q independent request queues in one vmapped
skyline dispatch (`admit_many`) before decoding the first queue's batch.
With ``--stream-chunks K`` the queues instead *arrive over time*: K
request waves feed a `StreamingAdmitter` whose device-resident fronts
are maintained incrementally (one insert dispatch per wave across all
queues) and admission happens from the final snapshot.

With ``--serve-loop N`` the driver additionally runs N Poisson-arriving
skyline queries through the async continuous-batching front-end
(`repro.serve.loop.ServeLoop`): dispatch-ahead double buffering
(``--dispatch-ahead`` waves in flight), deadline-aware admission with
load shedding (``--slo-ms`` per-request deadline), and p50/p99 latency
reporting.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --requests 16 --batch 4 --prompt-len 32 --gen 16 --queues 2 \
      --stream-chunks 4 --serve-loop 32 --slo-ms 200
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.parallel import SkyConfig
from repro.kernels.backend import available_backends
from repro.models import transformer as T
from repro.models.common import init_params
from repro.launch.mesh import make_engine_mesh
from repro.serve.scheduler import (Request, StreamingAdmitter,
                                   WindowedAdmitter, admit_many,
                                   make_default_engine)

__all__ = ["generate"]


def generate(params, cfg, tokens, gen: int, cache_len: int):
    """Greedy decode `gen` tokens after prefilling `tokens` (B, S)."""
    caches, logits = jax.jit(
        lambda p, t: T.prefill(p, cfg, {"tokens": t}, cache_len))(params,
                                                                  tokens)
    step = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
    s = tokens.shape[1]
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(gen):
        out.append(tok)
        caches, logits = step(params, caches, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--queues", type=int, default=1,
                    help="independent request queues admitted in one "
                         "engine dispatch")
    ap.add_argument("--engine-workers", type=int, default=0,
                    help="workers axis of the skyline engine's 2-D "
                         "(queries x workers) mesh; 0 = auto-factor the "
                         "device count. Admission fronts are small and "
                         "stay on the vmap path; the mesh serves the "
                         "large skyline-query batch this driver runs "
                         "when a mesh is present")
    ap.add_argument("--shard-threshold", type=int, default=4096,
                    help="padded query length at which engine.run "
                         "batches route through the sharded 2-D program")
    ap.add_argument("--stream-chunks", type=int, default=0,
                    help="admit from K request waves arriving over time "
                         "instead of one static pool: each wave is one "
                         "incremental insert dispatch into the "
                         "device-resident admission fronts (0 = static "
                         "admission)")
    ap.add_argument("--stream-arrivals", type=int, default=0,
                    help="requests per wave per queue in --stream-chunks "
                         "mode (0 = requests / chunks)")
    ap.add_argument("--window-epochs", type=int, default=0,
                    help="with --stream-chunks: admission fronts age out "
                         "— requests only count toward the front for the "
                         "last W waves (epoch-ring sliding windows, one "
                         "O(1) expiry dispatch per wave; 0 = unbounded "
                         "insert-only fronts)")
    ap.add_argument("--serve-loop", type=int, default=0,
                    help="serve N Poisson-arriving skyline queries "
                         "through the async continuous-batching loop "
                         "(dispatch-ahead + deadline-aware shedding) "
                         "and report p50/p99 latency (0 = skip)")
    ap.add_argument("--dispatch-ahead", type=int, default=2,
                    help="serve-loop in-flight wave window (1 disables "
                         "the host-pack/device-compute overlap)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="per-request latency SLO for --serve-loop; "
                         "requests predicted to miss it are shed "
                         "(0 = no deadlines)")
    ap.add_argument("--impl", default="auto",
                    choices=("auto",) + available_backends(),
                    help="kernel backend for the skyline engine "
                         "(resolved to a KernelSpec: fused sfs sweep + "
                         "dominance kernel impls; 'auto' picks pallas on "
                         "TPU, jnp elsewhere)")
    ap.add_argument("--tuning", default="",
                    help="path to a persisted kernel-tuning table "
                         "(repro.kernels.tuning JSON, e.g. from "
                         "`benchmarks.run --calibrate`); applied to the "
                         "engine so impl='auto' requests run the "
                         "calibrated (block, wtile) geometry. Defaults "
                         "to $REPRO_KERNEL_TUNING when unset")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_params(T.lm_plan(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    engine_kw = {"shard_threshold_n": args.shard_threshold}
    if args.engine_workers:
        engine_kw["mesh"] = make_engine_mesh(workers=args.engine_workers)
    engine = make_default_engine(SkyConfig(impl=args.impl), **engine_kw)
    if args.tuning:
        from repro.kernels.tuning import TuningTable
        engine.kernel_tuning = TuningTable.load(args.tuning)
    mesh_desc = (dict(engine.mesh.shape) if engine.mesh is not None
                 else "none (vmap-only)")
    tuned = engine.kernel_tuning
    print(f"[serve] skyline engine mesh: {mesh_desc}, kernel backend: "
          f"{engine.kernel_spec.name} (sweep={engine.kernel_spec.sweep}, "
          f"dominance={engine.kernel_spec.dominance})"
          + (f", tuned geometries: {len(tuned)}" if tuned else ""))

    # synthetic request queues with (slack, -priority, cost) criteria
    def make_queue(n):
        return Request(
            slack=jnp.asarray(rng.exponential(10.0, n), jnp.float32),
            neg_priority=jnp.asarray(-rng.integers(0, 3, n), jnp.float32),
            cost=jnp.asarray(rng.integers(8, 64, n), jnp.float32))

    if args.stream_chunks > 0:
        # arrival-time admission: maintain the fronts incrementally
        per_wave = (args.stream_arrivals
                    or max(args.requests // args.stream_chunks, 1))
        if args.window_epochs > 0:
            # sliding-window admission: requests age out after W waves
            adm = WindowedAdmitter(queues=args.queues, engine=engine,
                                   window_epochs=args.window_epochs)
            for wave in range(args.stream_chunks):
                adm.offer([make_queue(per_wave)
                           for _ in range(args.queues)])
                sizes = [f.shape[0] for f in adm.fronts()]
                aged = adm.tick() if wave < args.stream_chunks - 1 \
                    else False
                print(f"[serve] wave {wave}: +{per_wave} req/queue -> "
                      f"live-window front sizes {sizes}"
                      f"{' (oldest epoch aged out)' if aged else ''}")
        else:
            adm = StreamingAdmitter(queues=args.queues, engine=engine,
                                    backfill=True)
            for wave in range(args.stream_chunks):
                adm.offer([make_queue(per_wave)
                           for _ in range(args.queues)])
                sizes = [f.shape[0] for f in adm.fronts()]
                print(f"[serve] wave {wave}: +{per_wave} req/queue -> "
                      f"front sizes {sizes}")
        for qi, batch in enumerate(adm.admit(args.batch)):
            print(f"[serve] queue {qi}: admitted {batch.shape[0]} of "
                  f"{args.stream_chunks * per_wave} streamed requests "
                  f"(front-ranked"
                  f"{', second-layer backfilled' if args.window_epochs <= 0 else ''})")
        window_note = (f"window={args.window_epochs} epochs"
                       if args.window_epochs > 0 else "unbounded window")
        print(f"[serve] streaming admission: {args.stream_chunks} insert "
              f"dispatch(es)/queue-batch ({window_note}), fronts "
              f"device-resident throughout")
    else:
        queues = [make_queue(args.requests) for _ in range(args.queues)]
        admitted = admit_many(queues, args.batch, engine=engine)
        for qi, (picked, front) in enumerate(admitted):
            print(f"[serve] queue {qi}: admitted "
                  f"{list(np.asarray(picked))} "
                  f"(Pareto front size {int(np.asarray(front).sum())})")
        print(f"[serve] engine: {engine.queries_answered} admission "
              f"queries in {engine.batches_dispatched} dispatch(es)")

    if args.serve_loop > 0:
        from repro.serve.api import SkylineRequest
        from repro.serve.loop import ServeLoop
        slo = args.slo_ms / 1e3 if args.slo_ms > 0 else None
        arrivals = np.cumsum(rng.exponential(0.005, args.serve_loop))
        with ServeLoop(engine, depth=args.dispatch_ahead) as sloop:
            t0 = time.monotonic()
            tickets = []
            for dt_arr in arrivals:
                while time.monotonic() - t0 < dt_arr:
                    time.sleep(0.0005)
                now = time.monotonic()
                tickets.append(sloop.submit(SkylineRequest(
                    data=rng.random((256, 4)).astype(np.float32),
                    deadline=None if slo is None else now + slo)))
            sloop.drain()
            lats = sorted(t.latency for t in tickets
                          if t.status == "ok")
            shed = sum(t.status == "shed" for t in tickets)
            if lats:
                p50 = lats[len(lats) // 2] * 1e3
                p99 = lats[min(len(lats) - 1,
                               int(len(lats) * 0.99))] * 1e3
                print(f"[serve] serve-loop: {len(lats)} ok / {shed} "
                      f"shed, p50 {p50:.1f}ms p99 {p99:.1f}ms, "
                      f"{sloop.stats['waves']} waves "
                      f"(depth={args.dispatch_ahead})")
            else:
                print(f"[serve] serve-loop: all {shed} requests shed "
                      f"(SLO {args.slo_ms}ms infeasible on this host)")

    if engine.mesh is not None:
        # the 2-D mesh exists for large engine.run batches (admission
        # fronts are tiny and stay on the vmap path): drive one batch of
        # threshold-sized skyline queries through the sharded program
        sky = [jnp.asarray(rng.random((args.shard_threshold, 4)),
                           jnp.float32) for _ in range(2)]
        fronts = engine.run(sky)
        print(f"[serve] sharded skyline batch: {len(fronts)} queries of "
              f"n={args.shard_threshold} -> "
              f"{engine.sharded_dispatched} sharded dispatch(es), "
              f"front sizes {[int(b.count) for b, _ in fronts]}")

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    toks = generate(params, cfg, prompts,
                    args.gen, args.prompt_len + args.gen)
    dt = time.time() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    assert toks.shape == (args.batch, args.gen)


if __name__ == "__main__":
    main()
