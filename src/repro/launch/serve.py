"""Serving driver: Pareto-front (skyline) request admission + batched
prefill/greedy-decode.

Admission runs through the batched `SkylineEngine`: with ``--queues Q``
the driver admits from Q independent request queues in one vmapped
skyline dispatch (`admit_many`) before decoding the first queue's batch.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --requests 16 --batch 4 --prompt-len 32 --gen 16 --queues 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.common import init_params
from repro.serve.engine import SkylineEngine
from repro.serve.scheduler import Request, admit_many

__all__ = ["generate"]


def generate(params, cfg, tokens, gen: int, cache_len: int):
    """Greedy decode `gen` tokens after prefilling `tokens` (B, S)."""
    caches, logits = jax.jit(
        lambda p, t: T.prefill(p, cfg, {"tokens": t}, cache_len))(params,
                                                                  tokens)
    step = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
    s = tokens.shape[1]
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(gen):
        out.append(tok)
        caches, logits = step(params, caches, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--queues", type=int, default=1,
                    help="independent request queues admitted in one "
                         "engine dispatch")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_params(T.lm_plan(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    engine = SkylineEngine()

    # synthetic request queues with (slack, -priority, cost) criteria
    queues = [Request(
        slack=jnp.asarray(rng.exponential(10.0, args.requests),
                          jnp.float32),
        neg_priority=jnp.asarray(-rng.integers(0, 3, args.requests),
                                 jnp.float32),
        cost=jnp.asarray(rng.integers(8, 64, args.requests), jnp.float32))
        for _ in range(args.queues)]
    admitted = admit_many(queues, args.batch, engine=engine)
    for qi, (picked, front) in enumerate(admitted):
        print(f"[serve] queue {qi}: admitted {list(np.asarray(picked))} "
              f"(Pareto front size {int(np.asarray(front).sum())})")
    print(f"[serve] engine: {engine.queries_answered} admission queries "
          f"in {engine.batches_dispatched} dispatch(es)")

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    toks = generate(params, cfg, prompts,
                    args.gen, args.prompt_len + args.gen)
    dt = time.time() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    assert toks.shape == (args.batch, args.gen)


if __name__ == "__main__":
    main()
