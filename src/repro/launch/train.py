"""End-to-end training driver with checkpoint/restart fault tolerance.

CPU-runnable with --smoke (reduced configs); the full configs are meant
for the production mesh (see dryrun.py for the compile-only path).

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance: every step runs under a retry guard — on failure the
loop restores the last checkpoint (atomic on disk) and replays from
there. --fail-at N injects a one-shot failure for testing.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, latest_step
from repro.configs import get_config
from repro.data.pipeline import DataState, next_batch
from repro.models import transformer as T
from repro.models.common import init_params
from repro.train.optim import OptConfig
from repro.train.step import init_state, make_train_step

__all__ = ["train_loop"]


def train_loop(cfg, *, steps: int, batch: int, seq: int, ckpt_dir=None,
               ckpt_every: int = 50, opt_cfg: OptConfig | None = None,
               seed: int = 0, fail_at: int | None = None, log_every: int = 10,
               resume: bool = True):
    opt_cfg = opt_cfg or OptConfig(total_steps=steps)
    params = init_params(T.lm_plan(cfg), jax.random.PRNGKey(seed))
    state = init_state(params, opt_cfg)
    data = DataState(seed=seed + 1, step=0)
    start = 0

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume and latest_step(ckpt_dir) is not None:
        state, start, extra = mgr.restore(state)
        data = DataState(seed=extra.get("data_seed", seed + 1),
                         step=extra.get("data_step", start))
        print(f"[train] resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    history = []
    injected = {"done": fail_at is None}

    i = start
    while i < steps:
        try:
            b, data_next = next_batch(cfg, batch, seq, data)
            if not injected["done"] and i == fail_at:
                injected["done"] = True
                raise RuntimeError("injected failure (test)")
            state, metrics = step_fn(state, b)
            data = data_next
            if (i + 1) % log_every == 0 or i == start:
                loss = float(metrics["loss"])
                history.append((i + 1, loss))
                print(f"[train] step {i + 1:5d} loss={loss:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            if mgr and (i + 1) % ckpt_every == 0:
                mgr.save(i + 1, state, {"data_seed": data.seed,
                                        "data_step": data.step})
            i += 1
        except Exception as e:  # noqa: BLE001 — the fault-tolerance path
            if mgr is None or latest_step(mgr.dir) is None:
                raise
            print(f"[train] step {i} failed ({e}); restoring last "
                  "checkpoint and replaying")
            state, i, extra = mgr.restore(state)
            data = DataState(seed=extra["data_seed"],
                             step=extra["data_step"])
    if mgr:
        mgr.save(steps, state, {"data_seed": data.seed,
                                "data_step": data.step})
        mgr.wait()
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    t0 = time.time()
    state, history = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        opt_cfg=OptConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1)),
        fail_at=args.fail_at)
    dt = time.time() - t0
    losses = [l for _, l in history]
    print(f"[train] done {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert np.isfinite(losses[-1])


if __name__ == "__main__":
    main()
