"""Tuned launch environment for reproducible benchmark/serve runs.

Reported numbers are only comparable when every run sees the same
allocator and logging configuration: python's default malloc fragments
under the engine's host-staging churn (tcmalloc is the standard fix on
TPU/GPU hosts), TF/XLA banner logging perturbs short benchmarks, and an
unpinned ``XLA_FLAGS`` silently changes the host device count between
runs.  `build_env` derives the canonical environment, `apply_env` merges
it into ``os.environ`` (without clobbering anything the user pinned),
and ``python -m repro.launch.env CMD ...`` exec's a command under it —
the launch-script idiom, as one auditable module instead of a shell
file per host:

    python -m repro.launch.env python -m benchmarks.run --quick

Also plumbed here: ``REPRO_KERNEL_TUNING`` — the path to a persisted
kernel-tuning table (`repro.kernels.tuning`), so a calibrated
(block, wtile) table travels to every child process of a launch the
same way the allocator settings do.

LD_PRELOAD only takes effect at process start, so `apply_env` cannot
retro-tune the *current* process's allocator — use the ``-m`` exec form
(or export the returned mapping from a shell) for that; everything else
(logging, XLA flags) applies to late importers too.
"""

from __future__ import annotations

import os
import sys

__all__ = ["TCMALLOC_PATHS", "build_env", "apply_env", "main"]

# well-known tcmalloc locations (Debian/Ubuntu multiarch first — the
# path the TPU-host launch scripts preload)
TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def _find_tcmalloc() -> str | None:
    for path in TCMALLOC_PATHS:
        if os.path.exists(path):
            return path
    return None


def build_env(*, devices: int | None = None,
              tuning: str | None = None) -> dict[str, str]:
    """The canonical launch environment as a plain mapping.

    Args:
      devices: force this many host-platform devices via ``XLA_FLAGS``
        (None leaves the flag alone — the real accelerator count rules).
      tuning: path to a kernel-tuning table JSON to expose as
        ``REPRO_KERNEL_TUNING``.

    Returns only the variables this module owns; callers merge.
    """
    env: dict[str, str] = {
        # silence TF/XLA banner logging (perturbs short benchmarks)
        "TF_CPP_MIN_LOG_LEVEL": "4",
        # keep numpy's large-allocation warnings out of tcmalloc runs
        "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
    }
    tc = _find_tcmalloc()
    if tc is not None:
        env["LD_PRELOAD"] = tc
    if devices is not None:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={int(devices)}")
    if tuning is not None:
        env["REPRO_KERNEL_TUNING"] = tuning
    return env


def apply_env(*, devices: int | None = None, tuning: str | None = None,
              overwrite: bool = False) -> dict[str, str]:
    """Merge `build_env` into ``os.environ``; returns what was applied.

    User-pinned variables win unless ``overwrite=True``.  Note the
    LD_PRELOAD caveat in the module docstring — allocator preloading
    needs the exec form."""
    applied = {}
    for key, val in build_env(devices=devices, tuning=tuning).items():
        if overwrite or key not in os.environ:
            os.environ[key] = val
            applied[key] = val
    return applied


def main(argv: list[str] | None = None) -> None:
    """``python -m repro.launch.env [--devices N] [--tuning PATH] CMD...``
    — exec CMD under the tuned environment (LD_PRELOAD included)."""
    argv = list(sys.argv[1:] if argv is None else argv)
    devices = tuning = None
    while argv and argv[0].startswith("--"):
        flag = argv.pop(0)
        if flag == "--devices":
            devices = int(argv.pop(0))
        elif flag == "--tuning":
            tuning = argv.pop(0)
        else:
            sys.exit(f"unknown flag {flag!r} "
                     f"(have --devices N, --tuning PATH)")
    if not argv:
        sys.exit("usage: python -m repro.launch.env [--devices N] "
                 "[--tuning PATH] CMD [ARG ...]")
    env = dict(os.environ)
    env.update(build_env(devices=devices, tuning=tuning))
    os.execvpe(argv[0], argv, env)


if __name__ == "__main__":
    main()
