"""Mesh construction. Functions, not module-level constants, so importing
this module never touches jax device state (dry-run sets
xla_force_host_platform_device_count before first jax init)."""

from __future__ import annotations

import jax

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_worker_mesh", "make_local_mesh",
           "make_engine_mesh", "engine_mesh_shape"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) data x model = 256 chips. Multi-pod: 2 pods of
    256 = 512 chips with a leading 'pod' axis (data parallel across the
    slower DCN/pod links)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_worker_mesh(n: int | None = None):
    """Flat 1-D mesh over devices for the skyline library ('workers')."""
    n = n or len(jax.devices())
    return make_mesh((n,), ("workers",))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (subprocesses with forced host devices)."""
    return make_mesh((data, model), ("data", "model"))


def engine_mesh_shape(p: int, n_devices: int | None = None,
                      ) -> tuple[int, int]:
    """(queries, workers) factoring of the device count for a partition
    count `p`: workers is the largest power of two that divides both the
    device count and p (the fused program requires p % workers == 0),
    queries absorbs the rest."""
    ndev = n_devices or len(jax.devices())
    workers = 1
    while (workers * 2 <= ndev and p % (workers * 2) == 0
           and ndev % (workers * 2) == 0):
        workers *= 2
    return ndev // workers, workers


def make_engine_mesh(queries: int | None = None,
                     workers: int | None = None, *,
                     q_axis: str = "queries", w_axis: str = "workers"):
    """2-D (queries x workers) mesh for `SkylineEngine`'s sharded path.

    The outer axis shards the engine's query batch; the inner axis shards
    each query's partition buckets. With both sizes omitted every device
    lands on the workers axis (queries=1) — pass explicit sizes (or use
    `engine_mesh_shape`) to trade query-level for tuple-level
    parallelism. A `queries * workers` prefix of the device list is used,
    so the product may be smaller than the device count (it must divide
    into it exactly when only one size is given).
    """
    ndev = len(jax.devices())
    if queries is None and workers is None:
        queries, workers = 1, ndev
    elif queries is None:
        if ndev % workers:
            raise ValueError(f"workers={workers} must divide the device "
                             f"count {ndev} when queries is derived")
        queries = ndev // workers
    elif workers is None:
        if ndev % queries:
            raise ValueError(f"queries={queries} must divide the device "
                             f"count {ndev} when workers is derived")
        workers = ndev // queries
    if queries < 1 or workers < 1 or queries * workers > ndev:
        raise ValueError(
            f"engine mesh ({queries} x {workers}) needs "
            f"{queries * workers} devices, have {ndev}")
    return make_mesh((queries, workers), (q_axis, w_axis),
                     devices=jax.devices()[:queries * workers])
