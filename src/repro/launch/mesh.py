"""Mesh construction. Functions, not module-level constants, so importing
this module never touches jax device state (dry-run sets
xla_force_host_platform_device_count before first jax init)."""

from __future__ import annotations

import jax

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_worker_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) data x model = 256 chips. Multi-pod: 2 pods of
    256 = 512 chips with a leading 'pod' axis (data parallel across the
    slower DCN/pod links)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_worker_mesh(n: int | None = None):
    """Flat 1-D mesh over devices for the skyline library ('workers')."""
    n = n or len(jax.devices())
    return make_mesh((n,), ("workers",))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (subprocesses with forced host devices)."""
    return make_mesh((data, model), ("data", "model"))
