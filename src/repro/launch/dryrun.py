import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production meshes, record memory_analysis / cost_analysis / collective
bytes for the roofline (EXPERIMENTS.md §Dry-run, §Roofline).

The two lines above MUST stay first: jax locks the device count on first
init, and the 512 placeholder host devices exist only for this entry point
(smoke tests and benches see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod ...
  PYTHONPATH=src python -m repro.launch.dryrun --skyline        # fused
      skyline pipeline cells: the 1-D workers program at p=512 under
      both merge topologies (the flat all_gather union and the
      log2(p)-round pruning ppermute tree — tree_merge_p512 records
      the collective-term drop vs fused_p512), the 2-D (queries x
      workers) engine batch program, the streaming chunk-insert
      program, the isolated local-phase sweep, and the sliding-window
      (epoch-ring) chunk-insert program, all on the full 512 forced
      host devices
Results are cached incrementally in results/dryrun/<cell>.json.
"""

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.compat import set_mesh  # noqa: E402
from repro.configs import (ARCH_NAMES, SHAPES, arch_rules,  # noqa: E402
                           get_config, skip_reason)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import cache_specs, input_specs, state_specs  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.common import Sharder  # noqa: E402
from repro.train.optim import OptConfig  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],\s{}:#*TSED()]+?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|f8e4m3|f8e5m2|s8|u8|s16|u16|"
                       r"s32|u32|s64|u64|pred|c64)\[([0-9,]*)\]")

# wire-byte factor per collective kind (ring algorithms, per-chip bytes as
# a multiple of the per-device result bytes; documented approximation in
# EXPERIMENTS.md §Roofline)
_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Per-chip wire bytes by collective kind (the compiled module is the
    per-device program, so result shapes are already per-device)."""
    out = {k: 0.0 for k in _FACTOR}
    counts = {k: 0 for k in _FACTOR}
    for m in _COLL_RE.finditer(hlo_text):
        result_txt, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(result_txt) * _FACTOR[kind]
        counts[kind] += 1
    return out, counts


# hardware constants (TPU v5e-like target)
PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / link (per-chip collective proxy)


def lower_cell(arch: str, shape: str, multi_pod: bool,
               rules_override=None, opt_cfg: OptConfig | None = None,
               smoke: bool = False, cfg_override=None):
    cfg = cfg_override or get_config(arch, smoke=smoke)
    spec = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = arch_rules(cfg, shape, multi_pod=multi_pod)
    if rules_override:
        rules.update(rules_override)
    if opt_cfg is None:
        opt_cfg = OptConfig(
            moment_dtype="bfloat16" if cfg.param_dtype == "bfloat16"
            else "float32")
    sharder = Sharder(rules, enabled=True)

    with set_mesh(mesh):
        if spec.kind == "train":
            step_fn = make_train_step(cfg, opt_cfg, rules=rules,
                                      shard_activations=True)
            state = state_specs(cfg, mesh, rules, opt_cfg)
            batch = input_specs(cfg, shape, mesh, rules)
            lowered = jax.jit(step_fn, donate_argnums=(0,)).lower(
                state, batch)
        elif spec.kind == "prefill":
            params = state_specs(cfg, mesh, rules)["params"]
            batch = input_specs(cfg, shape, mesh, rules)

            if cfg.family == "encoder":
                # encoder "inference-prefill" = one full forward pass
                def prefill_fn(params, batch):
                    logits, _, _ = T.forward(params, cfg, batch,
                                             sharder=sharder)
                    return logits
            else:
                def prefill_fn(params, batch):
                    return T.prefill(params, cfg, batch, spec.seq_len,
                                     sharder=sharder)

            lowered = jax.jit(prefill_fn).lower(params, batch)
        else:  # decode
            params = state_specs(cfg, mesh, rules)["params"]
            caches = cache_specs(cfg, shape, mesh, rules)
            io = input_specs(cfg, shape, mesh, rules)

            def decode_fn(params, caches, token, pos):
                return T.decode_step(params, cfg, caches, token, pos,
                                     sharder=sharder)

            lowered = jax.jit(decode_fn, donate_argnums=(1,)).lower(
                params, caches, io["token"], io["pos"])
        compiled = lowered.compile()
    return cfg, mesh, lowered, compiled


def _probe_layers(cfg):
    """Two probe layer counts honouring structural periods."""
    if cfg.global_every:
        l0 = cfg.global_every * max(cfg.moe_every // cfg.global_every, 1)
    elif cfg.attn_every:
        l0 = cfg.attn_every
    else:
        l0 = 2
    return l0, 2 * l0


def _module_costs(compiled):
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    coll, counts = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            **{f"coll_{k}": v for k, v in coll.items()},
            **{f"cnt_{k}": float(v) for k, v in counts.items()}}


def probe_costs(arch, shape, multi_pod, rules_override=None):
    """Cost terms extrapolated from two fully-unrolled small-L probes.

    XLA's cost analysis counts while-loop bodies once, so the scanned
    (structural) lowering undercounts by the trip counts. Unrolled probes
    have no loops; costs are exactly linear in the (homogeneous) layer
    count and independent of the microbatch count at fixed token budget,
    so f(L) = c + body*L fits them exactly and evaluates at the full L.
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    l0, l1 = _probe_layers(cfg)
    vals = {}
    for L in (l0, l1):
        cfg_p = _dc.replace(cfg, n_layers=L, scan_unroll=True,
                            microbatches=1)
        _, _, _, compiled = lower_cell(arch, shape, multi_pod,
                                       rules_override, cfg_override=cfg_p)
        vals[L] = _module_costs(compiled)
    out = {}
    for key in vals[l0]:
        body = (vals[l1][key] - vals[l0][key]) / (l1 - l0)
        const = vals[l0][key] - l0 * body
        out[key] = const + cfg.n_layers * body
    out["probe_layers"] = [l0, l1]
    return out


def analyze(cfg, spec, mesh, compiled, *, seconds_compile: float,
            probed=None):
    chips = mesh.devices.size
    mem = compiled.memory_analysis()
    if probed is None:
        probed = _module_costs(compiled)
    coll = {k[5:]: v for k, v in probed.items() if k.startswith("coll_")}
    coll_counts = {k[4:]: v for k, v in probed.items()
                   if k.startswith("cnt_")}

    flops_per_chip = probed["flops"]
    bytes_per_chip = probed["bytes"]
    wire_per_chip = float(sum(coll.values()))

    compute_s = flops_per_chip / PEAK_FLOPS
    memory_s = bytes_per_chip / HBM_BW
    collective_s = wire_per_chip / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6*N_active*D for one global step of this cell
    n_active = cfg.active_param_count()
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        model_flops = 6 * n_active * tokens
    elif spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = spec.global_batch
        model_flops = 2 * n_active * tokens

    hlo_total_flops = flops_per_chip * chips
    return {
        "chips": chips,
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_chip": (mem.argument_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    + mem.output_size_in_bytes),
        },
        "cost_analysis": {"flops_per_chip": flops_per_chip,
                          "bytes_per_chip": bytes_per_chip,
                          "probe_layers": probed.get("probe_layers")},
        "collectives": {"per_chip_wire_bytes": coll, "counts": coll_counts},
        "roofline": {
            **terms,
            "dominant": dominant,
            "bound_s": max(terms.values()),
            "model_flops": model_flops,
            "hlo_total_flops": hlo_total_flops,
            "useful_flops_ratio": (model_flops / hlo_total_flops
                                   if hlo_total_flops > 0 else -1),
            "model_flops_time_s": model_flops / (chips * PEAK_FLOPS),
            "roofline_fraction": (
                (model_flops / (chips * PEAK_FLOPS)) / max(terms.values())
                if max(terms.values()) > 0 else -1),
        },
        "compile_seconds": seconds_compile,
    }


def run_cell(arch: str, shape: str, multi_pod: bool, rules_override=None,
             tag: str = "", smoke: bool = False):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cell = f"{arch}__{shape}__{'multipod' if multi_pod else 'pod'}{tag}"
    out_path = os.path.join(RESULTS_DIR, cell + ".json")
    cfg = get_config(arch)
    reason = skip_reason(cfg, shape)
    if reason:
        rec = {"cell": cell, "status": "skipped", "reason": reason}
    else:
        t0 = time.time()
        try:
            cfg, mesh, lowered, compiled = lower_cell(
                arch, shape, multi_pod, rules_override, smoke=smoke)
            probed = None
            if not smoke:
                probed = probe_costs(arch, shape, multi_pod, rules_override)
            rec = {"cell": cell, "status": "ok",
                   **analyze(cfg, SHAPES[shape], mesh, compiled,
                             seconds_compile=time.time() - t0,
                             probed=probed)}
        except Exception as e:  # noqa: BLE001 — recorded, not swallowed
            rec = {"cell": cell, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


# --------------------------------------------------------------------------
# Skyline-pipeline dry-run cells (the library this repo actually serves):
# lower + compile the fused partition+local+merge program on the full
# 512 forced host devices — the scale check the CPU test matrix (1/4/8
# devices, tests/test_distributed.py) cannot give.  Cell construction
# lives in repro.launch.cells (no env mutation at import), shared with
# the static verifier (repro.analysis).
# --------------------------------------------------------------------------

from repro.launch.cells import SKYLINE_CELLS, build_skyline_cell  # noqa: E402,F401


def run_skyline_cell(name: str, spec: dict, smoke: bool = False):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cell = f"skyline__{name}{'__smoke' if smoke else ''}"
    out_path = os.path.join(RESULTS_DIR, cell + ".json")
    t0 = time.time()
    try:
        built = build_skyline_cell(name, spec, smoke=smoke)
        fn, argspecs, mesh = built.fn, built.argspecs, built.mesh
        compiled = fn.lower(*argspecs).compile()
        mem = compiled.memory_analysis()
        probed = _module_costs(compiled)
        coll = {k[5:]: v for k, v in probed.items()
                if k.startswith("coll_")}
        terms = {"compute_s": probed["flops"] / PEAK_FLOPS,
                 "memory_s": probed["bytes"] / HBM_BW,
                 "collective_s": float(sum(coll.values())) / LINK_BW}
        rec = {"cell": cell, "status": "ok",
               "chips": mesh.devices.size if mesh is not None else 1,
               "config": {k: v for k, v in built.info.items()
                          if k != "mesh"},
               "memory_analysis": {
                   "argument_bytes": mem.argument_size_in_bytes,
                   "output_bytes": mem.output_size_in_bytes,
                   "temp_bytes": mem.temp_size_in_bytes,
                   "peak_bytes_per_chip": (mem.argument_size_in_bytes
                                           + mem.temp_size_in_bytes
                                           + mem.output_size_in_bytes)},
               "cost_analysis": {"flops_per_chip": probed["flops"],
                                 "bytes_per_chip": probed["bytes"]},
               "collectives": {
                   "per_chip_wire_bytes": coll,
                   "counts": {k[4:]: v for k, v in probed.items()
                              if k.startswith("cnt_")}},
               "roofline": {**terms,
                            "dominant": max(terms, key=terms.get)},
               "compile_seconds": time.time() - t0}
    except Exception as e:  # noqa: BLE001 — recorded, not swallowed
        rec = {"cell": cell, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (harness self-test)")
    ap.add_argument("--skyline", action="store_true",
                    help="dry-run the fused skyline pipeline cells "
                         "instead of the model cells")
    ap.add_argument("--cell", default=None,
                    help="with --skyline: run only this cell")
    args = ap.parse_args()

    if args.skyline:
        if args.cell and args.cell not in SKYLINE_CELLS:
            ap.error(f"unknown skyline cell {args.cell!r}; valid: "
                     f"{', '.join(SKYLINE_CELLS)}")
        n_ok = n_err = 0
        for name, spec in SKYLINE_CELLS.items():
            if args.cell and name != args.cell:
                continue
            cell = f"skyline__{name}{'__smoke' if args.smoke else ''}"
            path = os.path.join(RESULTS_DIR, cell + ".json")
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    rec = json.load(f)
                if rec.get("status") == "ok":
                    print(f"[cached] {cell}: ok")
                    n_ok += 1
                    continue
            rec = run_skyline_cell(name, spec, smoke=args.smoke)
            n_ok += rec["status"] == "ok"
            n_err += rec["status"] == "error"
            if rec["status"] == "ok":
                coll = rec["collectives"]["per_chip_wire_bytes"]
                print(f"[ok]     {cell}: chips={rec['chips']} "
                      f"dominant={rec['roofline']['dominant']} "
                      f"mem/chip={rec['memory_analysis']['peak_bytes_per_chip']/2**20:.1f}MiB "
                      f"ag_bytes={coll.get('all-gather', 0):.3e} "
                      f"compile={rec['compile_seconds']:.0f}s")
            else:
                print(f"[ERROR]  {cell}: {rec['error']}")
        print(f"done: ok={n_ok} err={n_err}")
        return

    archs = [args.arch] if args.arch else ARCH_NAMES
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_skip = n_err = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else list(SHAPES))
        for shape in shapes:
            for mp in meshes:
                tag = "__smoke" if args.smoke else ""
                cell = (f"{arch}__{shape}__"
                        f"{'multipod' if mp else 'pod'}{tag}")
                path = os.path.join(RESULTS_DIR, cell + ".json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        rec = json.load(f)
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[cached] {cell}: {rec['status']}")
                        continue
                rec = run_cell(arch, shape, mp, tag=tag, smoke=args.smoke)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                if st == "ok":
                    r = rec["roofline"]
                    print(f"[ok]     {cell}: dominant={r['dominant']} "
                          f"bound={r['bound_s']:.4f}s "
                          f"frac={r['roofline_fraction']:.3f} "
                          f"mem/chip={rec['memory_analysis']['peak_bytes_per_chip']/2**30:.2f}GiB "
                          f"compile={rec['compile_seconds']:.0f}s")
                elif st == "skipped":
                    print(f"[skip]   {cell}: {rec['reason']}")
                else:
                    print(f"[ERROR]  {cell}: {rec['error']}")
    print(f"done: ok={n_ok} skip={n_skip} err={n_err}")


if __name__ == "__main__":
    main()
