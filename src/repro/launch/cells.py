"""Skyline program-suite construction, shared by two consumers.

`repro.launch.dryrun` lowers + compiles these cells on 512 forced host
devices to record roofline/collective numbers; `repro.analysis.verifier`
lowers the same programs on whatever devices the process has and walks
the jaxpr/HLO asserting structural invariants (no host callbacks,
workers-only collectives, bounded Pallas VMEM).  The construction lives
here — and NOT in dryrun — because importing dryrun mutates
``os.environ['XLA_FLAGS']`` to force 512 devices at module top, which
would poison any other process importing it for the program builders.
This module performs no environment mutation and no device work at
import time.

``SKYLINE_CELLS`` are the five dry-run cells (their mesh sizes assume
the 512 forced devices; `build_skyline_cell(..., max_devices=N)` scales
the mesh axes down to the live topology, keeping the workers axis a
divisor of the partition count).  ``VERIFIER_EXTRA_CELLS`` adds the
programs the static verifier gates beyond the dry-run set: the engine's
vmap bucket program (must be collective-free), the fused window tick,
and the slab-backed stream feed with a reduced per-epoch capacity.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["SKYLINE_CELLS", "VERIFIER_EXTRA_CELLS", "BuiltCell",
           "build_skyline_cell"]


SKYLINE_CELLS = {
    # paper regime: one huge query, tuples partitioned across 512 workers
    "fused_p512": dict(kind="fused", n=1_000_000, d=4, p=512, workers=512,
                       capacity=16384, block=512),
    # same geometry under the log2(p)-round tree merge: the cost report
    # records the collective-term drop vs fused_p512 (each worker's
    # merge traffic is O(capacity) per round instead of the flat
    # all_gather's full p x C_loc union), and the Layer-2 verifier
    # enforces the boundary-size and round-count invariants on it
    "tree_merge_p512": dict(kind="fused", n=1_000_000, d=4, p=512,
                            workers=512, capacity=16384, block=512,
                            merge="tree"),
    # engine regime: a batch of large queries on a 2-D queries x workers
    # mesh (8 query shards x 64 workers = 512 chips)
    "batch_8x64": dict(kind="batch", q=8, n=262_144, d=4, p=64, queries=8,
                       workers=64, capacity=8192, block=512),
    # streaming regime: 8 live SkylineStates advanced by one chunk-insert
    # dispatch on the same 2-D mesh (states + chunks sharded over
    # queries, each chunk's partitions over workers)
    "stream_8x64": dict(kind="stream", q=8, n=65_536, d=4, p=64,
                        queries=8, workers=64, capacity=8192, block=512),
    # local phase in isolation: the fused SFS sweep over one worker's
    # partition batch (the per-device body of the local stage), lowered
    # so its cost terms are recorded alongside the pipeline cells
    "sweep_p64": dict(kind="sweep", n=16_384, d=4, p=64, capacity=4096,
                      block=512),
    # sliding-window regime: 8 live epoch-ring windows advanced by one
    # windowed chunk-insert dispatch on the same 2-D mesh (the head
    # epoch's batched insert — O(1) expiry happens in the tick program,
    # which is ring bookkeeping, not collective work)
    "window_8x64": dict(kind="window", q=8, n=65_536, d=4, p=64,
                        epochs=8, queries=8, workers=64, capacity=8192,
                        block=512),
}

# the additional programs the static verifier (repro.analysis) gates;
# sized small — the verifier compiles them on every CI run
VERIFIER_EXTRA_CELLS = {
    # engine bucket program on the pure-vmap path: the dispatch the
    # engine uses below shard_threshold_n.  Invariant: collective-free.
    "engine_vmap": dict(kind="vmap_batch", q=4, n=2048, d=4, p=4,
                        capacity=1024, block=64),
    # the fused serving tick (rotate ring + head insert + merged front)
    "window_tick": dict(kind="wtick", n=1024, d=4, p=4, epochs=4,
                        workers=4, capacity=512, block=64),
    # the slab-backed stream feed: gather leased slots + batched head
    # epoch insert + per-slot conditional scatter, with a per-epoch
    # capacity BELOW the full state capacity (the epoch_capacity
    # plumbing — the shape census asserts full C never crosses the
    # program edge)
    "slab_feed": dict(kind="slab_feed", q=4, slots=6, n=256, d=4, p=4,
                      epochs=4, rows=64, queries=2, workers=2,
                      capacity=512, block=64, epoch_capacity=100),
    # the serve loop's coalesced WAVE program: the same fused feed with
    # q tenants from MULTIPLE streams in one dispatch, per-tenant ring
    # heads, and the previous wave's unresolved pending record chained
    # in (pend=True — the fully-async promotion path). Invariants: no
    # host callbacks (nothing in the wave may sync), collective count
    # independent of the wave size, and the slab boundary discipline
    # of slab_feed
    "slab_wave": dict(kind="slab_wave", q=6, slots=8, n=256, d=4, p=4,
                      epochs=4, rows=64, queries=2, workers=2,
                      capacity=512, block=64, epoch_capacity=100),
    # the window-TILED sweep at a capacity whose untiled footprint blows
    # the 16 MiB/core VMEM cap (W x BC = 16384 x 512 = 8.4M elements,
    # ~26 MB untiled): the wtile=512 tile bounds the resident tests at
    # wtile x BC and the Layer-2 cap holds — the acceptance shape for
    # the tiling contract (tests/test_analysis.py asserts the untiled
    # estimate of this exact geometry exceeds the cap)
    "sweep_tiled": dict(kind="sweep", n=16_384, d=4, p=4,
                        capacity=16_384, block=512, wtile=512),
}


class BuiltCell(NamedTuple):
    """One constructed skyline program, ready to ``fn.lower(*argspecs)``."""
    name: str
    kind: str
    fn: Any
    argspecs: tuple
    mesh: Any  # jax.sharding.Mesh | None
    cfg: Any   # repro.core.parallel.SkyConfig
    info: dict


def _pow2_floor(x: int) -> int:
    b = 1
    while b * 2 <= x:
        b *= 2
    return b


def _scaled_axes(spec: dict, max_devices: int | None):
    """Mesh axis sizes ``(queries, workers)`` for the live topology.

    ``max_devices=None`` keeps the spec's sizes (the dry-run contract:
    512 forced devices).  Otherwise the workers axis is the largest
    power of two that fits the device budget AND divides the partition
    count, leaving room for >= 2 query shards where the topology allows
    (2-D cells keep both mesh axes exercised even on an 8-device CI
    host)."""
    want_q = spec.get("queries")
    want_w = spec.get("workers", 1)  # single-device cells carry no mesh
    if max_devices is None:
        return want_q, want_w
    ndev = max(int(max_devices), 1)
    p = spec["p"]
    if want_q is None:
        w = 1
        while w * 2 <= min(want_w, ndev) and p % (w * 2) == 0:
            w *= 2
        return None, w
    w_lim = max(1, ndev // 2) if ndev >= 4 else ndev
    w = 1
    while w * 2 <= min(want_w, w_lim) and p % (w * 2) == 0:
        w *= 2
    q = max(1, min(want_q, _pow2_floor(ndev // w)))
    return q, w


def build_skyline_cell(name: str, spec: dict, *, smoke: bool = False,
                       max_devices: int | None = None) -> BuiltCell:
    """Construct one cell's jitted program + argument specs (no compile).

    ``smoke`` shrinks the dry-run cells' data sizes (harness self-test);
    ``max_devices`` scales the mesh axes to the live topology (see
    `_scaled_axes`) — the verifier passes ``len(jax.devices())``, the
    dry-run harness passes None and gets the spec's full mesh."""
    from repro.compat import make_mesh
    from repro.core.incremental import (SkylineState, insert_chunk_batch_fn,
                                        state_capacity)
    from repro.core.parallel import (SkyConfig, fused_skyline_batch_fn,
                                     fused_skyline_fn)
    from repro.core.sfs import local_skyline_batch

    kind = spec["kind"]
    n = spec["n"] // (64 if smoke else 1)
    d = spec["d"]
    cfg = SkyConfig(strategy="sliced", p=spec["p"],
                    capacity=max(spec["capacity"] // (16 if smoke else 1),
                                 spec["block"]),
                    block=spec["block"], wtile=spec.get("wtile", 0),
                    bucket_factor=1.5, merge=spec.get("merge", "flat"))
    nq, nw = _scaled_axes(spec, max_devices)
    info = {"n": n, "d": d, "p": cfg.p, "capacity": cfg.capacity,
            "block": cfg.block}
    if "q" in spec:
        info["q"] = spec["q"]
    if "epochs" in spec:
        info["epochs"] = spec["epochs"]

    if kind == "fused":
        mesh = make_mesh((nw,), ("workers",))
        fn = fused_skyline_fn(cfg, mesh)
        argspecs = (jax.ShapeDtypeStruct((n, d), jnp.float32),
                    jax.ShapeDtypeStruct((n,), jnp.bool_),
                    jax.ShapeDtypeStruct((2,), jnp.uint32))
    elif kind == "sweep":
        # the fused local-phase sweep in isolation: one worker's
        # (p, n/p) partition batch through ONE dispatch.  Lowered
        # with the jnp sweep on CPU hosts ('auto' would pick the
        # Pallas grid on a TPU runtime); single-device program.
        mesh = None
        psz = n // spec["p"]
        fn = jax.jit(functools.partial(
            local_skyline_batch, capacity=cfg.capacity,
            block=cfg.block, impl="auto", wtile=cfg.wtile))
        argspecs = (
            jax.ShapeDtypeStruct((spec["p"], psz, d), jnp.float32),
            jax.ShapeDtypeStruct((spec["p"], psz), jnp.bool_))
    elif kind == "stream":
        mesh = make_mesh((nq, nw), ("queries", "workers"))
        fn = insert_chunk_batch_fn(cfg, mesh)
        q = spec["q"]
        c = state_capacity(cfg)
        state = SkylineState(
            points=jax.ShapeDtypeStruct((q, c, d), jnp.float32),
            mask=jax.ShapeDtypeStruct((q, c), jnp.bool_),
            count=jax.ShapeDtypeStruct((q,), jnp.int32),
            overflow=jax.ShapeDtypeStruct((q,), jnp.bool_),
            seen=jax.ShapeDtypeStruct((q,), jnp.int32),
            chunks=jax.ShapeDtypeStruct((q,), jnp.int32))
        argspecs = (state,
                    jax.ShapeDtypeStruct((q, n, d), jnp.float32),
                    jax.ShapeDtypeStruct((q, n), jnp.bool_),
                    jax.ShapeDtypeStruct((q, 2), jnp.uint32))
    elif kind == "window":
        from repro.core.windowed import (WindowedSkylineState,
                                         insert_window_batch_fn)
        mesh = make_mesh((nq, nw), ("queries", "workers"))
        fn = insert_window_batch_fn(cfg, mesh)
        q, e = spec["q"], spec["epochs"]
        c = state_capacity(cfg)
        state = WindowedSkylineState(
            points=jax.ShapeDtypeStruct((q, e, c, d), jnp.float32),
            mask=jax.ShapeDtypeStruct((q, e, c), jnp.bool_),
            count=jax.ShapeDtypeStruct((q, e), jnp.int32),
            overflow=jax.ShapeDtypeStruct((q, e), jnp.bool_),
            seen=jax.ShapeDtypeStruct((q, e), jnp.int32),
            chunks=jax.ShapeDtypeStruct((q, e), jnp.int32),
            head=jax.ShapeDtypeStruct((), jnp.int32),
            active=jax.ShapeDtypeStruct((), jnp.int32))
        argspecs = (state,
                    jax.ShapeDtypeStruct((q, n, d), jnp.float32),
                    jax.ShapeDtypeStruct((q, n), jnp.bool_),
                    jax.ShapeDtypeStruct((q, 2), jnp.uint32))
    elif kind == "batch":
        mesh = make_mesh((nq, nw), ("queries", "workers"))
        fn = fused_skyline_batch_fn(cfg, mesh)
        q = spec["q"]
        argspecs = (jax.ShapeDtypeStruct((q, n, d), jnp.float32),
                    jax.ShapeDtypeStruct((q, n), jnp.bool_),
                    jax.ShapeDtypeStruct((q, 2), jnp.uint32))
    elif kind == "vmap_batch":
        # the engine's small-bucket path: vmap over queries, no mesh —
        # the verifier asserts this program stays collective-free
        mesh = None
        fn = fused_skyline_batch_fn(cfg)
        q = spec["q"]
        argspecs = (jax.ShapeDtypeStruct((q, n, d), jnp.float32),
                    jax.ShapeDtypeStruct((q, n), jnp.bool_),
                    jax.ShapeDtypeStruct((q, 2), jnp.uint32))
    elif kind == "wtick":
        from repro.core.windowed import (WindowedSkylineState,
                                         window_tick_fn)
        _, nw1 = _scaled_axes(dict(spec, queries=None), max_devices)
        mesh = make_mesh((nw1,), ("workers",))
        fn = window_tick_fn(cfg, mesh)
        e = spec["epochs"]
        c = state_capacity(cfg)
        state = WindowedSkylineState(
            points=jax.ShapeDtypeStruct((e, c, d), jnp.float32),
            mask=jax.ShapeDtypeStruct((e, c), jnp.bool_),
            count=jax.ShapeDtypeStruct((e,), jnp.int32),
            overflow=jax.ShapeDtypeStruct((e,), jnp.bool_),
            seen=jax.ShapeDtypeStruct((e,), jnp.int32),
            chunks=jax.ShapeDtypeStruct((e,), jnp.int32),
            head=jax.ShapeDtypeStruct((), jnp.int32),
            active=jax.ShapeDtypeStruct((), jnp.int32))
        argspecs = (state,
                    jax.ShapeDtypeStruct((n, d), jnp.float32),
                    jax.ShapeDtypeStruct((n,), jnp.bool_),
                    jax.ShapeDtypeStruct((2,), jnp.uint32),
                    jax.ShapeDtypeStruct((), jnp.bool_))
    elif kind in ("slab_feed", "slab_wave"):
        from repro.core.windowed import epoch_rows
        from repro.serve.engine import _slab_feed_fn
        mesh = make_mesh((nq, nw), ("queries", "workers"))
        q, e, rows = spec["q"], spec["epochs"], spec["rows"]
        s = spec["slots"]
        cap = epoch_rows(cfg, spec["epoch_capacity"])
        npend = 1 if kind == "slab_wave" else 0
        info["rows"], info["epoch_cap"] = rows, cap
        fn = _slab_feed_fn(cfg, rows, q, mesh, "queries", "workers", cap,
                           npend)
        leaves = (
            jax.ShapeDtypeStruct((s, e, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((s, e, rows), jnp.bool_),
            jax.ShapeDtypeStruct((s, e), jnp.int32),
            jax.ShapeDtypeStruct((s, e), jnp.bool_),
            jax.ShapeDtypeStruct((s, e), jnp.int32),
            jax.ShapeDtypeStruct((s, e), jnp.int32))
        argspecs = (leaves,
                    jax.ShapeDtypeStruct((q,), jnp.int32),   # slot idx
                    jax.ShapeDtypeStruct((q,), jnp.int32),   # ring heads
                    jax.ShapeDtypeStruct((q, n, d), jnp.float32),
                    jax.ShapeDtypeStruct((q, n), jnp.bool_),
                    jax.ShapeDtypeStruct((q, 2), jnp.uint32))
        if npend:
            # the previous wave's full-cap inserted states + the wave
            # position/selection/epoch vectors of the chained pending
            # record (the epoch column is what lets records parked at
            # non-head ring slots ride along without a blocking settle)
            pend_leaves = (
                jax.ShapeDtypeStruct((q, cap, d), jnp.float32),
                jax.ShapeDtypeStruct((q, cap), jnp.bool_),
                jax.ShapeDtypeStruct((q,), jnp.int32),
                jax.ShapeDtypeStruct((q,), jnp.bool_),
                jax.ShapeDtypeStruct((q,), jnp.int32),
                jax.ShapeDtypeStruct((q,), jnp.int32))
            argspecs = argspecs + (
                pend_leaves,
                jax.ShapeDtypeStruct((q,), jnp.int32),
                jax.ShapeDtypeStruct((q,), jnp.bool_),
                jax.ShapeDtypeStruct((q,), jnp.int32))
    else:
        raise ValueError(f"unknown skyline cell kind {kind!r}")

    if mesh is not None:
        info["mesh"] = dict(zip(mesh.axis_names,
                                (int(mesh.shape[a])
                                 for a in mesh.axis_names)))
    return BuiltCell(name, kind, fn, argspecs, mesh, cfg, info)
