"""Abstract input/state specs for the dry-run: ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, zero device allocation) for every model
input and the full train state."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.models import transformer as T
from repro.models.common import abstract_params
from repro.sharding import zero1_shardings
from repro.train.optim import OptConfig

__all__ = ["input_specs", "state_specs", "cache_specs"]


def _sds(shape, dtype, mesh, spec):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _axes_spec(axes, rules):
    return P(*(rules.get(a) if a is not None else None for a in axes))


def input_specs(cfg, shape_name: str, mesh=None, rules=None):
    """Batch stand-ins for a shape cell. For decode shapes this is the
    (token, pos) pair — the KV caches come from cache_specs()."""
    rules = rules or {}
    spec = SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    bspec = P(rules.get("batch"))

    if spec.kind in ("train", "prefill"):
        if cfg.family == "encoder":
            return {"frames": _sds((b, s, cfg.frontend_dim), jnp.float32,
                                   mesh, P(rules.get("batch"), None, None)),
                    "labels": _sds((b, s), jnp.int32, mesh, bspec)}
        if cfg.family == "vlm":
            st = s - cfg.prefix_len
            out = {"image_emb": _sds((b, cfg.prefix_len, cfg.frontend_dim),
                                     jnp.float32, mesh,
                                     P(rules.get("batch"), None, None)),
                   "tokens": _sds((b, st), jnp.int32, mesh, bspec)}
            if spec.kind == "train":
                out["labels"] = _sds((b, st), jnp.int32, mesh, bspec)
            return out
        out = {"tokens": _sds((b, s), jnp.int32, mesh, bspec)}
        if spec.kind == "train":
            out["labels"] = _sds((b, s), jnp.int32, mesh, bspec)
        return out

    # decode: one new token against a cache of seq_len
    return {"token": _sds((b, 1), jnp.int32, mesh, P(rules.get("batch"))),
            "pos": _sds((), jnp.int32, mesh, P())}


def cache_specs(cfg, shape_name: str, mesh=None, rules=None):
    rules = rules or {}
    spec = SHAPES[shape_name]

    def factory(shape, dtype, axes):
        return _sds(shape, dtype, mesh, _axes_spec(axes, rules))

    return T.init_caches(cfg, spec.global_batch, spec.seq_len,
                         factory=factory)


def state_specs(cfg, mesh=None, rules=None, opt_cfg: OptConfig | None = None):
    """Abstract TrainState: params + AdamW moments (ZeRO-1-sharded)."""
    opt_cfg = opt_cfg or OptConfig()
    plan = T.lm_plan(cfg)
    pdt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    params = abstract_params(plan, mesh, rules, dtype=pdt)
    mdt = jnp.bfloat16 if opt_cfg.moment_dtype == "bfloat16" else jnp.float32

    if mesh is not None:
        msh = zero1_shardings(plan, rules, mesh)
        moments = jax.tree.map(
            lambda p, s: jax.ShapeDtypeStruct(p.shape, mdt, sharding=s),
            params, msh)
    else:
        moments = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, mdt), params)

    opt = {"m": moments, "v": moments,
           "step": _sds((), jnp.int32, mesh, P())}
    if opt_cfg.compress == "int8":
        opt["err"] = params
    return {"params": params, "opt": opt,
            "step": _sds((), jnp.int32, mesh, P())}
