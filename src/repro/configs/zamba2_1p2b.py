"""Zamba2-1.2B: Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]. 38 mamba2 layers; the shared attn+MLP block is
applied before every 6th layer (7 applications)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000, head_dim=64,
    ssm_state=64, ssm_heads=64, ssm_head_dim=64, attn_every=6,
    microbatches=8)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
    ssm_state=16, ssm_heads=4, ssm_head_dim=16, ssm_chunk=8, attn_every=2)
