"""Llama-4-Maverick 400B-A17B: MoE 128e top-1 (every 2nd layer) + shared
expert; iRoPE interleaved attention (3 chunked-local + 1 global NoPE)
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

param_dtype=bfloat16: 400B params with f32 master + f32 Adam moments need
~6.4 TB > the 4 TB of a 256-chip v5e pod; bf16 params/moments fit
(DESIGN.md §6). Real runs use larger meshes or fp8."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
    head_dim=128, n_experts=128, top_k=1, moe_every=2, shared_expert=True,
    attn_kind="chunk", chunk=8192, global_every=4, rope_theta=5e5,
    param_dtype="bfloat16", microbatches=32)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke", family="moe", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    n_experts=4, top_k=1, moe_every=2, shared_expert=True,
    attn_kind="chunk", chunk=16, global_every=4)
