"""Mixtral-8x7B: 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000, n_experts=8,
    top_k=2, attn_kind="window", window=4096, rope_theta=1e6,
    param_dtype="bfloat16", microbatches=8)  # bf16: 47B f32 params+grads
    # alone exceed 16 GB/chip at TP-16 (DESIGN.md §6, as llama4)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, n_experts=4, top_k=2,
    attn_kind="window", window=16)
