"""Yi-6B: llama-arch dense GQA [arXiv:2403.04652; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=4, d_ff=11008, vocab=64000, rope_theta=5e6,
    microbatches=8)

SMOKE = ModelConfig(
    name="yi-6b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, rope_theta=5e6)
