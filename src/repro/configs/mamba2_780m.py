"""Mamba2-780m: pure SSM (SSD) [arXiv:2405.21060; unverified].
d_inner = 2*d_model = 3072 -> 48 heads x 64."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab=50280, ssm_state=128,
    ssm_heads=48, ssm_head_dim=64, ssm_chunk=256, microbatches=2)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab=256, ssm_state=16,
    ssm_heads=4, ssm_head_dim=16, ssm_chunk=8)
