"""PaliGemma-3B: SigLIP vision stub + gemma decoder, prefix-LM
[arXiv:2407.07726; hf]. input_specs() provides precomputed 1152-d SigLIP
patch embeddings (256 patches)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, d_ff=16384, vocab=257216, head_dim=256,
    mlp_kind="geglu", frontend_dim=1152, prefix_len=256, microbatches=4)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=1, d_ff=128, vocab=256, head_dim=16,
    mlp_kind="geglu", frontend_dim=32, prefix_len=8)
