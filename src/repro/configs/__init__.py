"""Architecture registry: 10 assigned archs (+ smoke variants)."""

from __future__ import annotations

import importlib

from repro.configs.base import (SHAPES, ShapeSpec, applicable_shapes,
                                arch_rules, skip_reason)

_MODULES = {
    "yi-6b": "yi_6b",
    "qwen3-14b": "qwen3_14b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "starcoder2-7b": "starcoder2_7b",
    "zamba2-1.2b": "zamba2_1p2b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-780m": "mamba2_780m",
    "hubert-xlarge": "hubert_xlarge",
    "paligemma-3b": "paligemma_3b",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str, smoke: bool = False):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


__all__ = ["SHAPES", "ShapeSpec", "applicable_shapes", "arch_rules",
           "skip_reason", "ARCH_NAMES", "get_config"]
