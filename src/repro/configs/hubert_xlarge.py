"""HuBERT-XLarge: encoder-only audio transformer [arXiv:2106.07447;
unverified]. Modality frontend is a stub: input_specs() provides
precomputed 512-d conv-frontend frame embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504, head_dim=80,
    mlp_kind="gelu", frontend_dim=512, tie_embeddings=False,
    microbatches=4)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="encoder", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=64, head_dim=16,
    mlp_kind="gelu", frontend_dim=16, tie_embeddings=False)
