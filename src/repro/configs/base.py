"""Shape grid, applicability rules, and per-arch sharding-rule derivation.

Shapes (assignment): train_4k / prefill_32k / decode_32k / long_500k.
``decode_*``/``long_*`` lower serve_step (one token against a KV cache of
seq_len), not train_step. Skips (DESIGN.md §Arch-applicability): pure
full-attention archs skip long_500k; encoder-only archs skip decode shapes.
"""

from __future__ import annotations

import dataclasses

from repro.models.common import DEFAULT_RULES
from repro.models.config import ModelConfig

__all__ = ["SHAPES", "applicable_shapes", "skip_reason", "arch_rules",
           "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def _sub_quadratic(cfg: ModelConfig) -> bool:
    """Can this arch decode at 500k without a full quadratic KV cache?
    SSM/hybrid: constant state (+ seq-sharded shared-attn KV). SWA /
    chunked-local attention: bounded KV (llama4's global-NoPE layers keep a
    full but seq-shardable cache — iRoPE's long-context design)."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    if cfg.attn_kind in ("window", "chunk"):
        return True
    if cfg.global_every:  # iRoPE mix
        return True
    return False


def skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    spec = SHAPES[shape]
    if cfg.family == "encoder" and spec.kind == "decode":
        return "encoder-only: no decode step"
    if shape == "long_500k" and not _sub_quadratic(cfg):
        return "pure full-attention arch: long_500k needs sub-quadratic attention"
    return None


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    return [s for s in SHAPES if skip_reason(cfg, s) is None]


def arch_rules(cfg: ModelConfig, shape: str, *, model_axis: int = 16,
               data_axis: int = 16, multi_pod: bool = False) -> dict:
    """Derive logical-axis rules for (arch x shape) with divisibility
    fallbacks (DESIGN.md §6). This is the baseline; §Perf hillclimbs
    override individual entries."""
    rules = dict(DEFAULT_RULES)
    spec = SHAPES[shape]

    def div(n, ax):  # can dim of size n shard over axis ax?
        return n > 0 and n % ax == 0

    # tensor-parallel fallbacks; when attention heads cannot shard over
    # the model axis, fall back to sequence-parallel attention (the
    # quadratic (Sq, Sk) intermediates shard over q-seq instead)
    heads = cfg.ssm_heads if cfg.family in ("ssm",) else cfg.n_heads
    if not div(heads, model_axis):
        rules["heads"] = None
        # shard the attention *weights* on head_dim instead (otherwise
        # L x (wq + wo) would be fully replicated — GiBs at 14B scale)
        if div(cfg.head_dim_eff, model_axis):
            rules["head_dim"] = "model"
        if spec.kind in ("train", "prefill") and \
                spec.seq_len % model_axis == 0:
            rules["attn_seq"] = "model"
            # Megatron-SP residual stream: keep x sequence-sharded
            # BETWEEN blocks too, so each block is all-gather(x) in,
            # reduce-scatter(y) out (bf16), instead of re-replicating
            # the f32 residual/grad per layer (§Perf hillclimb 1b)
            rules["seq"] = "model"
    if not div(cfg.n_kv_heads, model_axis):
        rules["kv_heads"] = None
    if cfg.d_ff and not div(cfg.d_ff, model_axis):
        rules["mlp"] = None
    if cfg.n_experts:
        if div(cfg.n_experts, model_axis):
            # 2-D expert sharding: experts over model x expert-hidden over
            # data — weights stay resident, the inter-einsum partial sums
            # travel (generic weight-FSDP was tried and refuted: per-
            # microbatch weight gathers cost 293 s collective on mixtral)
            rules["expert"] = "model"
            # 2nd weight dim over data via expert_embed (expert_mlp over
            # data would collide with the token-sharded dispatch buffer)
            if div(cfg.d_model, data_axis):
                rules["expert_embed"] = "data"
        else:
            rules["expert"], rules["expert_mlp"] = None, "model"
            # few big experts (mixtral): TP over model + FSDP the expert
            # weights' embed dim over data (params dominate per-chip
            # memory; the per-layer weight gather is ~60 MB/mat)
            if div(cfg.d_model, data_axis):
                rules["expert_embed"] = "data"

    # batch / sequence shardings per shape
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    total_batch_shards = data_axis * (2 if multi_pod else 1)
    if not div(spec.global_batch, total_batch_shards):
        if div(spec.global_batch, data_axis):
            rules["batch"] = ("data",)
        else:
            rules["batch"] = None
    else:
        rules["batch"] = batch_axes

    if spec.kind == "prefill":
        # the produced KV caches dominate prefill memory: shard their
        # sequence dim over the model axis (kv_heads then stays
        # replicated on the cache to avoid same-axis-twice specs)
        rules["kv_heads"] = None
        rules["kv_seq"] = "model"
    if spec.kind == "decode":
        # KV cache is the dominant buffer: shard its sequence dim. The
        # mesh "model" axis then carries the cache, so kv_heads must stay
        # replicated on the cache (same-axis-twice is invalid SPMD), and
        # q-heads must NOT shard over model either: a heads-sharded q
        # against a seq-sharded cache makes GSPMD all-gather the cache
        # every layer (measured: 34 GB/chip wire on yi-6b). Per-token
        # tensors are tiny - replicate them, shard the weights on
        # head_dim instead.
        rules["kv_heads"] = None
        rules["heads"] = None
        if div(cfg.head_dim_eff, model_axis):
            rules["head_dim"] = "model"
        if cfg.n_experts and rules.get("expert") == "model":
            # serving: expert weights must be resident — the train-time
            # expert_embed/data (FSDP) dim would be all-gathered per
            # decoded token (measured 97 GB/chip on llama4); shard the
            # expert hidden dim over data instead (§Perf hillclimb 4)
            rules["expert_embed"] = None
            rules["expert_mlp"] = ("data" if div(cfg.d_ff, data_axis)
                                   else None)
        if spec.global_batch == 1:
            # long-context: context parallelism — the paper's SLICED idea
            # applied to the KV sequence (DESIGN.md §6)
            rules["batch"] = None
            rules["kv_seq"] = ("data", "model")
        else:
            rules["kv_seq"] = "model"
    return rules
