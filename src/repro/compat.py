"""Version-portable wrappers over jax APIs that moved between releases.

The repo targets the current jax API (``jax.shard_map``,
``jax.sharding.set_mesh``, ``jax.make_mesh(axis_types=...)``) but must
also run on jax 0.4.x, where shard_map lives in
``jax.experimental.shard_map`` (with ``check_rep`` instead of
``check_vma``), meshes have no axis types, and there is no ambient-mesh
setter beyond the legacy ``with mesh:`` context. Every call site in the
repo goes through this module so the divergence lives in exactly one
place.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax

__all__ = ["shard_map", "make_mesh", "set_mesh", "ambient_mesh"]

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_SET_MESH = hasattr(jax.sharding, "set_mesh")

try:  # axis types exist only on newer jax
    from jax.sharding import AxisType as _AxisType  # noqa: F401
    _HAS_AXIS_TYPES = True
except ImportError:
    _AxisType = None
    _HAS_AXIS_TYPES = False


def ambient_mesh():
    """The mesh installed by :func:`set_mesh` (None when unset)."""
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover — internal layout changed
        return None


def shard_map(f, mesh=None, *, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on new jax; the experimental one on 0.4.x.

    ``check_vma`` maps onto the old ``check_rep`` flag. ``mesh=None``
    resolves to the ambient mesh installed by :func:`set_mesh` (the new
    API does this natively; on old jax we look it up explicitly).
    """
    if _HAS_NEW_SHARD_MAP:
        kwargs: dict[str, Any] = dict(in_specs=in_specs,
                                      out_specs=out_specs,
                                      check_vma=check_vma)
        if mesh is not None:
            kwargs["mesh"] = mesh
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _sm
    mesh = mesh if mesh is not None else ambient_mesh()
    if mesh is None:
        raise ValueError(
            "shard_map without an explicit mesh requires an ambient mesh "
            "(repro.compat.set_mesh) on this jax version")
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _HAS_AXIS_TYPES:
        kwargs["axis_types"] = (_AxisType.Auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


@contextlib.contextmanager
def set_mesh(mesh):
    """Ambient-mesh context: ``jax.sharding.set_mesh`` on new jax, the
    legacy ``with mesh:`` resource context on 0.4.x."""
    if _HAS_SET_MESH:
        with jax.sharding.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
