"""Serving example: batched multi-query skylines + Pareto-front request
admission, both through the `SkylineEngine`'s request surface
(`SkylineRequest` -> `submit_many`), an async serve-loop pass with
deadlines, then batched prefill/greedy decode on the framework's model
stack.

  PYTHONPATH=src python examples/serving_pareto.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import SkyConfig
from repro.core.datagen import generate as gen_points
from repro.launch.serve import generate
from repro.models import transformer as T
from repro.models.common import init_params
from repro.serve.api import SkylineRequest
from repro.serve.engine import SkylineEngine
from repro.serve.loop import ServeLoop
from repro.serve.scheduler import Request, admit


def main():
    rng = np.random.default_rng(0)

    # --- batched skyline queries: 8 users, each caring about a different
    # subset of the catalogue's attributes, answered in ONE dispatch ---
    engine = SkylineEngine(SkyConfig(strategy="sliced", p=4, capacity=512,
                                     block=64, bucket_factor=4.0))
    catalogue = gen_points("anticorrelated", jax.random.PRNGKey(7), 400, 4)
    dim_masks = jnp.asarray(rng.random((8, 4)) < 0.6).at[:, 0].set(True)
    t0 = time.time()
    # requests sharing one `data` object stack into a single broadcast
    # dispatch (the subspace-view fast path)
    views = engine.submit_many([
        SkylineRequest(data=catalogue, subspace=m) for m in dim_masks])
    sizes = [int(buf.count) for buf, _ in views]
    print(f"engine: {len(views)} subspace skyline queries in "
          f"{engine.batches_dispatched} dispatch(es), "
          f"{time.time() - t0:.2f}s; front sizes {sizes}")

    # --- the same engine behind the async serve loop: Poisson-ish
    # arrivals, dispatch-ahead double buffering, per-request deadlines ---
    with ServeLoop(engine, depth=2) as loop:
        tickets = [loop.submit(SkylineRequest(
            data=gen_points("uniform", jax.random.PRNGKey(50 + i),
                            int(rng.integers(100, 300)), 4),
            deadline=time.monotonic() + 5.0)) for i in range(6)]
        loop.drain()
    lat = [t.latency * 1e3 for t in tickets if t.status == "ok"]
    print(f"serve loop: {len(lat)} queries ok over "
          f"{loop.stats['waves']} wave(s), worst latency "
          f"{max(lat):.1f}ms (host pack overlapped with device compute)")

    # --- engine-backed admission: 32 queued requests ---
    reqs = Request(
        slack=jnp.asarray(rng.exponential(10.0, 32), jnp.float32),
        neg_priority=jnp.asarray(-rng.integers(0, 3, 32), jnp.float32),
        cost=jnp.asarray(rng.integers(8, 64, 32), jnp.float32))
    picked, front = admit(reqs, batch_size=4, engine=engine)
    picked = np.asarray(picked)
    print(f"Pareto front: {int(np.asarray(front).sum())} of 32 requests; "
          f"admitted batch: {list(picked)}")
    for i in picked:
        print(f"  req {i:2d}: slack={float(reqs.slack[i]):5.1f}s "
              f"prio={-int(reqs.neg_priority[i])} "
              f"cost={int(reqs.cost[i])} tok "
              f"{'(front)' if bool(front[i]) else ''}")

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = init_params(T.lm_plan(cfg), jax.random.PRNGKey(0))
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)
    t0 = time.time()
    toks = generate(params, cfg, prompts, gen=16, cache_len=64)
    dt = time.time() - t0
    print(f"generated {toks.shape[0]}x{toks.shape[1]} tokens in {dt:.2f}s "
          f"(smoke-size MoE model, CPU)")


if __name__ == "__main__":
    main()
