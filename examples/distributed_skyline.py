"""Distributed skyline on a device mesh (shard_map over 'workers'):
partition-per-device local skylines, representative broadcast, NoSeq
parallel merge. Re-execs itself with forced host devices so the mesh has
8 workers on CPU.

  PYTHONPATH=src python examples/distributed_skyline.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import SkyConfig, parallel_skyline, skyline  # noqa: E402
from repro.core.datagen import generate  # noqa: E402
from repro.launch.mesh import make_worker_mesh  # noqa: E402


def main():
    mesh = make_worker_mesh()
    print(f"mesh: {mesh.devices.size} workers")
    pts = generate("anticorrelated", jax.random.PRNGKey(0), 40_000, 4)
    ref = skyline(pts, capacity=8192)

    for noseq in (False, True):
        cfg = SkyConfig(strategy="sliced", p=16, capacity=8192,
                        local_capacity=1024, rep_filter="sorted",
                        noseq=noseq)
        t0 = time.perf_counter()
        buf, stats = parallel_skyline(pts, cfg=cfg, mesh=mesh)
        jax.block_until_ready(buf.points)
        dt = time.perf_counter() - t0
        sizes = np.asarray(stats["local_sizes"])
        assert int(buf.count) == int(ref.count), (buf.count, ref.count)
        print(f"{'NoSeq' if noseq else 'seq-merge':9s}: "
              f"|SKY|={int(buf.count)}  local sizes "
              f"min/max={sizes.min()}/{sizes.max()}  "
              f"union={int(stats['union_size'])}  ({dt:.2f}s)")
    print("distributed == sequential: OK")


if __name__ == "__main__":
    main()
