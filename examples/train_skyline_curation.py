"""End-to-end training driver: LM training with skyline (Pareto-front)
batch curation — the paper's technique as the data-selection layer
(DESIGN.md §4).

Every step draws a 2x-oversized candidate batch, scores each example on
three criteria (hardness = -loss, brevity penalty, staleness), and keeps
a batch built Pareto-front-first via the skyline. The model is the
framework's own transformer stack.

  PYTHONPATH=src python examples/train_skyline_curation.py           # ~15M
  PYTHONPATH=src python examples/train_skyline_curation.py --model-100m
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataState, make_batch
from repro.data.selection import pareto_select
from repro.models import transformer as T
from repro.models.common import init_params
from repro.models.config import ModelConfig
from repro.train.optim import OptConfig
from repro.train.step import init_state, make_train_step


def model_config(big: bool) -> ModelConfig:
    if big:  # ~100M params
        return ModelConfig(name="lm-100m", family="dense", n_layers=10,
                           d_model=640, n_heads=10, n_kv_heads=5,
                           d_ff=2560, vocab=16384, microbatches=1)
    return ModelConfig(name="lm-15m", family="dense", n_layers=6,
                       d_model=320, n_heads=8, n_kv_heads=4, d_ff=1280,
                       vocab=8192, microbatches=1)


def per_example_loss(params, cfg, batch):
    logits, _, _ = T.forward(params, cfg, batch)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    nll = lse - jnp.sum(logits * onehot, -1)
    return jnp.mean(nll, axis=-1)  # (B,)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--curate", action=argparse.BooleanOptionalAction,
                    default=True)
    args = ap.parse_args()

    cfg = model_config(args.model_100m)
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params), "
          f"curation={'on' if args.curate else 'off'}")
    params = init_params(T.lm_plan(cfg), jax.random.PRNGKey(0))
    opt = OptConfig(lr=1e-3, total_steps=args.steps,
                    warmup_steps=max(args.steps // 20, 1))
    state = init_state(params, opt)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    loss_fn = jax.jit(lambda p, b: per_example_loss(p, cfg, b))

    data = DataState(seed=1, step=0)
    t0 = time.time()
    first = last = None
    for i in range(args.steps):
        if args.curate:
            # oversample 2x, keep the Pareto-front-first half
            cand = make_batch(cfg, args.batch * 2, args.seq, data)
            data = data.advance()
            losses = loss_fn(state["params"], cand)
            lengths = jnp.sum(cand["labels"] >= 0, axis=-1)
            recency = jnp.arange(args.batch * 2, dtype=jnp.float32)
            crit = jnp.stack([-losses, -lengths.astype(jnp.float32),
                              recency], axis=-1)
            idx, front = pareto_select(crit, args.batch)
            batch = jax.tree.map(lambda x: x[idx], cand)
        else:
            batch = make_batch(cfg, args.batch, args.seq, data)
            data = data.advance()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if (i + 1) % 25 == 0:
            print(f"step {i + 1:4d} loss={loss:.4f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    print(f"loss {first:.4f} -> {last:.4f} over {args.steps} steps")
    assert np.isfinite(last) and last < first


if __name__ == "__main__":
    main()
