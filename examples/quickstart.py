"""Quickstart: compute skylines sequentially and in parallel.

  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax

from repro.core import SkyConfig, parallel_skyline, skyline
from repro.core.datagen import generate


def main():
    key = jax.random.PRNGKey(0)
    n, d = 50_000, 4
    pts = generate("anticorrelated", key, n, d)
    print(f"dataset: {n} tuples, {d} dims, anticorrelated")

    # --- sequential block-SFS (paper Algorithm 1) ---
    t0 = time.perf_counter()
    sky = skyline(pts, capacity=8192)
    jax.block_until_ready(sky.points)
    print(f"sequential SFS: |SKY| = {int(sky.count)} "
          f"({time.perf_counter() - t0:.2f}s incl. compile)")

    # --- parallel pattern (paper Algorithm 2) with each strategy ---
    for strategy in ["random", "grid", "angular", "sliced"]:
        cfg = SkyConfig(strategy=strategy, p=8, capacity=8192,
                        local_capacity=2048,
                        bucket_factor=8.0 if strategy == "grid" else 3.0,
                        rep_filter="sorted")
        t0 = time.perf_counter()
        buf, stats = parallel_skyline(pts, cfg=cfg)
        jax.block_until_ready(buf.points)
        assert int(buf.count) == int(sky.count)
        print(f"parallel {strategy:8s}: |SKY| = {int(buf.count)}, "
              f"union = {int(stats['union_size'])}, "
              f"overflow = {bool(buf.overflow)} "
              f"({time.perf_counter() - t0:.2f}s)")

    # --- NoSeq: fully parallel second phase (paper §4.2) ---
    cfg = SkyConfig(strategy="sliced", p=8, capacity=8192,
                    local_capacity=2048, rep_filter="sorted", noseq=True)
    buf, stats = parallel_skyline(pts, cfg=cfg)
    assert int(buf.count) == int(sky.count)
    print(f"NoSeq(sliced+):    |SKY| = {int(buf.count)} — phase 2 runs "
          f"per-worker against the potential-dominator sets")

    print("done — all strategies agree with the sequential skyline")


if __name__ == "__main__":
    main()
