"""Streaming example: device-resident skyline maintenance over arriving
data, plus incrementally maintained Pareto-front request admission.

A product catalogue arrives in waves (new listings every few minutes); a
serving layer must expose the current Pareto front — cheapest / fastest /
best — after every wave without re-scanning history. `SkylineEngine.
open_stream` keeps one `SkylineState` per tenant on device — leased from
the engine's shared slab arena, so thousands of tenants share one set of
device buffers: each wave is ONE insert dispatch for all tenants, and
`snapshot()` is bit-for-bit what a full recompute over everything seen
so far would return.

The sliding-window scenario adds time decay: listings expire after W
waves (`open_stream(d, StreamOptions(window_epochs=W))` — an epoch ring
per tenant).
`tick()` ages every tenant's window in one O(1) dispatch; a member the
expired wave had been suppressing resurfaces automatically, because each
epoch retains its own local skyline (the retained candidates) and the
front is merged on read.

  PYTHONPATH=src python examples/streaming_pareto.py
"""

import time

import jax
import numpy as np

from repro.core import SkyConfig
from repro.core.datagen import generate
from repro.serve.engine import SkylineEngine, StreamOptions
from repro.serve.scheduler import Request, StreamingAdmitter


def main():
    rng = np.random.default_rng(0)
    engine = SkylineEngine(SkyConfig(strategy="sliced", p=4, capacity=512,
                                     block=64, bucket_factor=4.0))

    # --- two tenants' catalogues arriving in ragged waves ---------------
    stream = engine.open_stream(d=4, options=StreamOptions(q=2))
    dists = ("anticorrelated", "uniform")
    t0 = time.time()
    for wave in range(5):
        sizes = rng.integers(40, 200, size=2)
        chunks = [generate(dist, jax.random.PRNGKey(10 * wave + j), int(n),
                           4)
                  for j, (dist, n) in enumerate(zip(dists, sizes))]
        if wave == 3:
            chunks[1] = None  # tenant 1 idle this wave
        stream.feed(chunks)
        c = stream.counters()
        print(f"wave {wave}: arrivals {[0 if ch is None else len(ch) for ch in chunks]}"
              f" -> front sizes {c['count'].tolist()} "
              f"(seen {c['seen'].tolist()})")
    fronts = stream.snapshot()
    print(f"{stream.chunks_fed} waves in {time.time() - t0:.2f}s; final "
          f"fronts: {[int(b.count) for b in fronts]} members "
          f"(device-resident throughout, zero recomputes)")

    # --- sliding window: listings expire after 3 waves ------------------
    win = engine.open_stream(d=4, options=StreamOptions(q=2,
                                                        window_epochs=3))
    for wave in range(6):
        chunks = [generate(dist, jax.random.PRNGKey(100 * wave + j),
                           int(n), 4)
                  for j, (dist, n) in enumerate(
                      zip(dists, rng.integers(40, 200, size=2)))]
        win.feed(chunks)
        fronts = [int(b.count) for b in win.snapshot()]
        aged = win.tick() if wave < 5 else False
        print(f"window wave {wave}: live-window fronts {fronts}"
              f"{'  (oldest wave aged out, O(1))' if aged else ''}")
    c = win.counters()
    print(f"sliding window: retained candidates {c['count'].tolist()} "
          f"across 3 epochs/tenant; expiry never recomputes — dominance "
          f"across epochs is resolved when the front is read")

    # one arena per (d, dtype, epochs, slot-rows) bucket serves ALL
    # tenant streams: device buffers are O(#buckets), not O(#streams)
    print(f"slab arenas: "
          f"{[(k, v['slots'], v['leased']) for k, v in sorted(engine.arena_report().items())]}")

    # --- streaming admission: the request pool trickles in --------------
    adm = StreamingAdmitter(queues=2, engine=engine)
    for wave in range(4):
        adm.offer([Request(
            slack=jax.numpy.asarray(rng.exponential(10.0, 16),
                                    jax.numpy.float32),
            neg_priority=jax.numpy.asarray(-rng.integers(0, 3, 16),
                                           jax.numpy.float32),
            cost=jax.numpy.asarray(rng.integers(8, 64, 16),
                                   jax.numpy.float32)) for _ in range(2)])
        print(f"admission wave {wave}: front sizes "
              f"{[f.shape[0] for f in adm.fronts()]} of "
              f"{(wave + 1) * 16} offered per queue")
    for qi, batch in enumerate(adm.admit(4)):
        print(f"queue {qi}: admit {batch.shape[0]} most-urgent front "
              f"requests; criteria rows:\n{np.round(batch, 2)}")


if __name__ == "__main__":
    main()
