"""Roofline report generator: reads results/dryrun/*.json and emits the
EXPERIMENTS.md §Dry-run and §Roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh pod|multipod]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun")

ARCH_ORDER = ["yi-6b", "qwen3-14b", "phi4-mini-3.8b", "starcoder2-7b",
              "zamba2-1.2b", "llama4-maverick-400b-a17b", "mixtral-8x7b",
              "mamba2-780m", "hubert-xlarge", "paligemma-3b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tag: str = ""):
    recs = {}
    for f in glob.glob(os.path.join(RESULTS, f"*__{mesh}{tag}.json")):
        r = json.load(open(f))
        parts = os.path.basename(f)[:-5].split("__")
        recs[(parts[0], parts[1])] = r
    return recs


def fix_note(rec, arch, shape):
    dom = rec["roofline"]["dominant"]
    if dom == "memory_s":
        return ("reduce unfused intermediate traffic: fuse softmax/norm "
                "chains, bf16 intermediates, larger microbatches")
    if dom == "collective_s":
        return ("cut resharding: align layer in/out shardings, "
                "reduce-scatter instead of all-reduce for grads")
    return "increase arithmetic intensity (larger per-chip tiles)"


def table(mesh: str, tag: str = "") -> str:
    recs = load(mesh, tag)
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| peak GiB/chip | MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | skipped: "
                             f"{r['reason']} | | | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | ERROR | | | | | | | |")
                continue
            ro = r["roofline"]
            mem = r["memory_analysis"]["peak_bytes_per_chip"] / 2 ** 30
            lines.append(
                f"| {arch} | {shape} | {ro['compute_s']:.4f} | "
                f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
                f"{ro['dominant'].replace('_s', '')} | {mem:.2f} | "
                f"{ro['model_flops']:.3e} | "
                f"{min(ro['useful_flops_ratio'], 1.0):.3f} | "
                f"{ro['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def summary(mesh: str):
    recs = load(mesh)
    ok = sum(r["status"] == "ok" for r in recs.values())
    skip = sum(r["status"] == "skipped" for r in recs.values())
    err = sum(r["status"] == "error" for r in recs.values())
    over = [(k, r["memory_analysis"]["peak_bytes_per_chip"] / 2 ** 30)
            for k, r in recs.items() if r["status"] == "ok"
            and r["memory_analysis"]["peak_bytes_per_chip"] > 16 * 2 ** 30]
    return ok, skip, err, over


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(table(args.mesh, args.tag))
    ok, skip, err, over = summary(args.mesh)
    print(f"\ncells ok={ok} skipped={skip} errors={err}")
    if over:
        print("over 16GiB/chip (CPU-backend f32-inflated upper bound):")
        for k, g in over:
            print(f"  {k}: {g:.2f} GiB")


if __name__ == "__main__":
    main()
