"""Shared benchmark harness: timing, CSV output, staged skyline timing."""

from __future__ import annotations

import time

import jax

from repro.core import naive_skyline_mask
from repro.core.parallel import (SkyConfig, local_stage, merge_stage,
                                 partition_stage)

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn, *args, warmup: int = 1, repeat: int = 3) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def staged_skyline_fns(cfg: SkyConfig):
    """Jitted per-phase callables for phase-time measurements (paper
    Fig 4a/4b): partition, local (phase 1), merge (phase 2)."""

    @jax.jit
    def part(pts):
        buckets, meta, stats = partition_stage(pts, None, cfg)
        return buckets, stats

    def _meta(pts):
        _, meta, _ = partition_stage(pts, None, cfg)
        return meta

    @jax.jit
    def local(buckets_points, buckets_mask):
        sky, stats = local_stage(buckets_points, buckets_mask, cfg)
        return sky, stats

    def merge_fn(meta):
        @jax.jit
        def merge(sky):
            return merge_stage(sky, meta, cfg)
        return merge

    return part, local, merge_fn, _meta


def run_pipeline_staged(pts, cfg: SkyConfig):
    """Returns (t_partition, t_local, t_merge, stats dict)."""
    part, local, merge_fn, meta_fn = staged_skyline_fns(cfg)
    t_part = timeit(part, pts)
    buckets, pstats = part(pts)
    t_local = timeit(local, buckets.points, buckets.mask)
    sky, lstats = local(buckets.points, buckets.mask)
    merge = merge_fn(meta_fn(pts))
    t_merge = timeit(merge, sky)
    final, mstats = merge(sky)
    stats = {**{k: v for k, v in pstats.items()},
             **{k: v for k, v in lstats.items()},
             **{k: v for k, v in mstats.items()},
             "final_count": final.count,
             "overflow": (final.overflow | lstats["local_overflow"]
                          | pstats["bucket_overflow"])}
    return t_part, t_local, t_merge, stats


def verify_exact(pts, buf) -> bool:
    import numpy as np
    want = set(map(tuple, np.asarray(pts)[np.asarray(
        naive_skyline_mask(pts))]))
    got = set(map(tuple, np.asarray(buf.points)[np.asarray(buf.mask)]))
    return got == want
