"""Benchmark entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
  PYTHONPATH=src python -m benchmarks.run --only fig4,fig5
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import figures

    quick_sizes = (5_000, 20_000)
    suite = {
        "fig3": lambda: figures.fig3_filtering(
            n=20_000 if args.quick else 50_000),
        "grid_filter": lambda: figures.grid_filtering_table(
            n=20_000 if args.quick else 50_000),
        "fig4": lambda: figures.fig4_partitioning(
            sizes=quick_sizes if args.quick else (10_000, 30_000, 100_000)),
        "fig5": lambda: figures.fig5_improved(
            sizes=quick_sizes if args.quick else (10_000, 30_000, 100_000)),
        "fig6": lambda: figures.fig6_dimensions(
            n=10_000 if args.quick else 30_000,
            dims=(2, 4, 6) if args.quick else (2, 3, 4, 5, 6, 7)),
        "fig7a": lambda: figures.fig7_partitions(
            n=20_000 if args.quick else 50_000),
        "fig7b": lambda: figures.fig7_cores(
            n=10_000 if args.quick else 30_000),
        "kernel": figures.kernel_microbench,
        "throughput": lambda: figures.throughput_queries_per_sec(
            q=32, n=64 if args.quick else 128),
    }
    only = [s for s in args.only.split(",") if s]
    unknown = [s for s in only if s not in suite]
    if unknown:
        sys.exit(f"unknown suite name(s) {unknown}; "
                 f"valid: {', '.join(sorted(suite))}")
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in suite.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        fn()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
