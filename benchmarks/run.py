"""Benchmark entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
  PYTHONPATH=src python -m benchmarks.run --only fig4,fig5
  PYTHONPATH=src python -m benchmarks.run --json results/bench.json
  PYTHONPATH=src python -m benchmarks.run --calibrate   # calibration
      passes only: data-derived shard_threshold_n (vmap vs sharded
      dispatch) + the kernel (block, wtile) tuning table, persisted to
      --tuning-json for REPRO_KERNEL_TUNING / serve --tuning

Every selected suite runs even if an earlier one raises; failures print
their traceback immediately, are recorded in the ``--json`` report, and
make the process exit non-zero at the end — CI can both archive the
results and fail the step.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="",
                    help="write per-suite status + emitted rows to this "
                         "path (parent dirs are created)")
    ap.add_argument("--calibrate", action="store_true",
                    help="run only the calibration passes: the engine "
                         "pass (vmap vs sharded dispatch -> data-derived "
                         "shard_threshold_n) and the kernel pass "
                         "(candidate (block, wtile) geometries -> "
                         "persisted tuning table)")
    ap.add_argument("--tuning-json", default="results/kernel_tuning.json",
                    help="where the kernel_autotune suite persists the "
                         "tuning-table artifact (repro.kernels.tuning)")
    args = ap.parse_args()

    from benchmarks import common, figures

    quick_sizes = (5_000, 20_000)
    suite = {
        "fig3": lambda: figures.fig3_filtering(
            n=20_000 if args.quick else 50_000),
        "grid_filter": lambda: figures.grid_filtering_table(
            n=20_000 if args.quick else 50_000),
        "fig4": lambda: figures.fig4_partitioning(
            sizes=quick_sizes if args.quick else (10_000, 30_000, 100_000)),
        "fig5": lambda: figures.fig5_improved(
            sizes=quick_sizes if args.quick else (10_000, 30_000, 100_000)),
        "fig6": lambda: figures.fig6_dimensions(
            n=10_000 if args.quick else 30_000,
            dims=(2, 4, 6) if args.quick else (2, 3, 4, 5, 6, 7)),
        "fig7a": lambda: figures.fig7_partitions(
            n=20_000 if args.quick else 50_000),
        "fig7b": lambda: figures.fig7_cores(
            n=10_000 if args.quick else 30_000),
        "kernel": figures.kernel_microbench,
        "kernel_autotune": lambda: figures.kernel_autotune(
            quick=args.quick, path=args.tuning_json),
        "local_phase": lambda: figures.local_phase(
            n_max=16_384, quick=args.quick),
        "throughput": lambda: figures.throughput_queries_per_sec(
            q=32, n=64 if args.quick else 128),
        "throughput_sharded": lambda: figures.throughput_sharded(
            q=4, n=16_384 if args.quick else 32_768),
        "streaming": lambda: figures.streaming_maintenance(
            n=16_384, chunk_counts=(8,) if args.quick else (2, 4, 8, 16)),
        "sliding_window": lambda: figures.sliding_window(
            n=16_384, epoch_counts=(8,) if args.quick else (2, 4, 8, 16)),
        "serving_latency": lambda: figures.serving_latency(
            bursts=6 if args.quick else 12),
        "feed_memory": lambda: figures.feed_memory(quick=args.quick),
        "merge_scaling": lambda: figures.merge_scaling(
            n_per_worker=6_000 if args.quick else 12_500,
            repeat=2 if args.quick else 4),
        "calibration": figures.calibration,
    }
    only = [s for s in args.only.split(",") if s]
    if args.calibrate:
        only = ["calibration", "kernel_autotune"]
    unknown = [s for s in only if s not in suite]
    if unknown:
        sys.exit(f"unknown suite name(s) {unknown}; "
                 f"valid: {', '.join(sorted(suite))}")
    print("name,us_per_call,derived")
    t0 = time.time()
    report: dict[str, dict] = {}
    for name, fn in suite.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        row_start = len(common.ROWS)
        ts = time.time()
        try:
            ret = fn()
            report[name] = {"status": "ok",
                            "seconds": round(time.time() - ts, 3),
                            "rows": common.ROWS[row_start:]}
            if isinstance(ret, (int, float, str, bool)):
                report[name]["result"] = ret
        except Exception as e:  # noqa: BLE001 — recorded AND fatal below
            traceback.print_exc()
            report[name] = {"status": "error",
                            "seconds": round(time.time() - ts, 3),
                            "error": f"{type(e).__name__}: {e}",
                            "traceback": traceback.format_exc()[-4000:],
                            "rows": common.ROWS[row_start:]}
            print(f"# !!! {name} FAILED: {type(e).__name__}: {e}",
                  flush=True)
    total = time.time() - t0
    if args.json:
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({"total_seconds": round(total, 3),
                       "quick": args.quick, "suites": report}, f, indent=1)
        print(f"# json report -> {args.json}", file=sys.stderr)
    print(f"# total {total:.1f}s", file=sys.stderr)
    failed = sorted(n for n, r in report.items() if r["status"] != "ok")
    if failed:
        sys.exit(f"benchmark suite(s) failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
