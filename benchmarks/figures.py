"""One benchmark per paper table/figure (DESIGN.md §8 index).

Scale note: the paper ran N up to 100M on 120 cores; this container has 1
core, so defaults are N in {10K..100K} with identical distributions. All
reported trends are the paper's own work-count trends (times in seconds,
plus the size statistics the paper plots).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, run_pipeline_staged, timeit
from repro.core.datagen import generate
from repro.core.filtering import (filter_by_representatives, grid_filter,
                                  select_representatives)
from repro.core.parallel import SkyConfig

DISTS = ["uniform", "correlated", "anticorrelated"]


def _cfg(strategy, n, p=8, **kw):
    base = dict(strategy=strategy, p=p, capacity=8192, block=256,
                local_capacity=2048,
                bucket_factor={"grid": 8.0, "angular": 3.0}.get(strategy,
                                                                1.0))
    base.update(kw)
    return SkyConfig(**base)


def _critical_path(stats, cfg, final_count):
    """Dominance-test counts on the parallel critical path (the quantity a
    p-core cluster divides; single-core wall time cannot show the NoSeq
    win, this metric does — DESIGN.md §3 change 4)."""
    import numpy as np
    sizes = np.asarray(stats["local_sizes"])
    union = int(sizes.sum())
    if cfg.noseq:
        # worker i: |u_i| x |pd_i| tests; pd per strategy
        if cfg.strategy == "sliced":
            pd = np.cumsum(sizes) - sizes
        else:
            pd = union - sizes
        return int(np.max(sizes * np.maximum(pd, 1)))
    return int(union * max(final_count, 1))  # one sequential pass


def fig3_filtering(n=50_000, d=4):
    """Paper Fig 3: % tuples discarded by representative filtering,
    SORTED vs REGION, per distribution."""
    for dist in DISTS:
        pts = generate(dist, jax.random.PRNGKey(3), n, d)
        mask = jnp.ones(n, bool)
        for strat in ["sorted", "region"]:
            @jax.jit
            def run(pts, mask):
                reps, rmask = select_representatives(
                    pts, mask, 64, strategy=strat)
                return filter_by_representatives(pts, mask, reps, rmask)
            t = timeit(run, pts, mask)
            kept = run(pts, mask)
            frac = 1.0 - float(jnp.sum(kept)) / n
            emit(f"fig3/{dist}/{strat}", t * 1e6,
                 f"discarded_frac={frac:.3f}")


def grid_filtering_table(n=50_000, d=4, m=4):
    """Paper §5.1 in-text: Grid Filtering discard % per distribution."""
    for dist in DISTS:
        pts = generate(dist, jax.random.PRNGKey(4), n, d)

        @jax.jit
        def run(pts):
            return grid_filter(pts, jnp.ones(pts.shape[0], bool), m)
        t = timeit(run, pts)
        gf = run(pts)
        emit(f"grid_filter/{dist}", t * 1e6,
             f"discarded_frac={float(gf.dropped) / n:.3f}")


def fig4_partitioning(sizes=(10_000, 30_000, 100_000), d=4):
    """Paper Fig 4: plain strategies on ANT — total time (4a), local
    skyline time (4b), local skyline sizes (4c)."""
    for n in sizes:
        pts = generate("anticorrelated", jax.random.PRNGKey(5), n, d)
        for strat in ["random", "grid", "angular", "sliced"]:
            cfg = _cfg(strat, n)
            tp, tl, tm, stats = run_pipeline_staged(pts, cfg)
            union = int(stats["union_size"])
            final = int(stats["final_count"])
            emit(f"fig4/{strat}/n={n}", (tp + tl + tm) * 1e6,
                 f"t_local_us={tl * 1e6:.0f};t_merge_us={tm * 1e6:.0f};"
                 f"local_sky_total={union};final={final};"
                 f"crit_tests={_critical_path(stats, cfg, final)}")


def fig5_improved(sizes=(10_000, 30_000, 100_000), d=4):
    """Paper Fig 5: SLICED+/ANGULAR+ (representative filtering) and NoSeq
    on ANT."""
    for n in sizes:
        pts = generate("anticorrelated", jax.random.PRNGKey(6), n, d)
        variants = {
            "sliced": _cfg("sliced", n),
            "sliced+": _cfg("sliced", n, rep_filter="sorted", rep_k=16),
            "angular": _cfg("angular", n),
            "angular+": _cfg("angular", n, rep_filter="sorted", rep_k=16),
            "noseq(sliced+)": _cfg("sliced", n, rep_filter="sorted",
                                   rep_k=16, noseq=True),
        }
        for name, cfg in variants.items():
            tp, tl, tm, stats = run_pipeline_staged(pts, cfg)
            final = int(stats["final_count"])
            emit(f"fig5/{name}/n={n}", (tp + tl + tm) * 1e6,
                 f"t_merge_us={tm * 1e6:.0f};final={final};"
                 f"union={int(stats['union_size'])};"
                 f"crit_tests={_critical_path(stats, cfg, final)}")


def fig6_dimensions(n=30_000, dims=(2, 3, 4, 5, 6, 7)):
    """Paper Fig 6: improved strategies vs dimensionality (ANT + the two
    real-data surrogates)."""
    for dataset in ["anticorrelated", "hou", "res"]:
        for d in dims:
            if dataset == "anticorrelated":
                pts = generate(dataset, jax.random.PRNGKey(7), n, d)
            else:
                from repro.core.datagen import load_real
                pts = load_real(dataset, n=n, d=d)
            # ANT skylines explode with d (the curse-of-dimensionality
            # effect the paper plots): scale buffer capacities with d
            cap = 8192 if d <= 4 else 32768
            lcap = 2048 if d <= 4 else 8192
            for name, cfg in {
                "sliced+": _cfg("sliced", n, rep_filter="sorted",
                                capacity=cap, local_capacity=lcap),
                "angular+": _cfg("angular", n, rep_filter="sorted",
                                 capacity=cap, local_capacity=lcap),
                "noseq": _cfg("sliced", n, rep_filter="sorted",
                              noseq=True, capacity=cap,
                              local_capacity=lcap),
            }.items():
                tp, tl, tm, stats = run_pipeline_staged(pts, cfg)
                emit(f"fig6/{dataset}/{name}/d={d}",
                     (tp + tl + tm) * 1e6,
                     f"final={int(stats['final_count'])};"
                     f"overflow={bool(stats['overflow'])}")
            if dataset != "anticorrelated":
                break  # real surrogates are fixed at d=7; one row each


def fig7_partitions(n=50_000, d=4, parts=(4, 8, 16, 32, 64)):
    """Paper Fig 7a: partition-count sweep — NoSeq degrades when p grows
    (union of local skylines balloons)."""
    pts = generate("anticorrelated", jax.random.PRNGKey(8), n, d)
    for p in parts:
        for name, cfg in {
            "sliced+": _cfg("sliced", n, p=p, rep_filter="sorted"),
            "noseq": _cfg("sliced", n, p=p, rep_filter="sorted",
                          noseq=True),
        }.items():
            tp, tl, tm, stats = run_pipeline_staged(pts, cfg)
            emit(f"fig7a/{name}/p={p}", (tp + tl + tm) * 1e6,
                 f"union={int(stats['union_size'])};"
                 f"t_merge_us={tm * 1e6:.0f}")


def fig7_cores(n=30_000, d=4):
    """Paper Fig 7b: core-count sweep. Adapted (DESIGN.md §3 change 4):
    one physical core — we sweep host *device* counts in subprocesses and
    report wall time + per-device work share."""
    import os
    import subprocess
    import sys
    import textwrap
    for devices in (1, 2, 4, 8):
        code = textwrap.dedent(f"""
            import time, jax
            from repro.core.datagen import generate
            from repro.core.parallel import SkyConfig, parallel_skyline
            from repro.launch.mesh import make_worker_mesh
            pts = generate("anticorrelated", jax.random.PRNGKey(8),
                           {n}, {d})
            mesh = make_worker_mesh()
            cfg = SkyConfig(strategy="sliced", p=8, capacity=8192,
                            block=256, rep_filter="sorted")
            buf, _ = parallel_skyline(pts, cfg=cfg, mesh=mesh)  # compile
            jax.block_until_ready(buf.points)
            t0 = time.perf_counter()
            buf, _ = parallel_skyline(pts, cfg=cfg, mesh=mesh)
            jax.block_until_ready(buf.points)
            print(time.perf_counter() - t0)
        """)
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=900)
        assert r.returncode == 0, r.stderr[-1500:]
        t = float(r.stdout.strip().splitlines()[-1])
        emit(f"fig7b/devices={devices}", t * 1e6,
             f"partitions_per_device={8 // devices if devices <= 8 else 1}")


def local_phase(n_max=16384, d=4, parts=8, quick=False):
    """Local-phase SFS cost: the seed per-pair path (dominance kernel
    dispatched once per (window-block, candidate-block) pair inside a
    fori_loop) vs the fused one-dispatch sweep, through the same
    `local_skyline_batch` entry — only the kernel geometry differs.

    Measures the single-partition scan at n up to 16k, the batched
    partition shape the parallel pipeline's local stage runs (P=8
    partitions in ONE dispatch), the interpret-mode Pallas body at a
    small n (CPU emulation is slow; the row exists to track the kernel
    body's cost, not to win), and the window-tile panel: tiled vs
    untiled sweeps at n=16k plus the W >> block stress shape whose
    untiled footprint the VMEM cap rejects.  The panel ends by running
    the autotuner (`repro.kernels.tuning.calibrate_kernels`) on this
    host and asserting its pick is never slower than the hand-set
    default geometry.  Returns the fused-jnp speedup over per-pair at
    n=n_max.
    """
    import time as _time

    from repro.core.sfs import local_skyline_batch

    cap, blk = 2048, 256
    speedup = None

    def bench(tag, pts, variants, repeat=11):
        """Interleaved best-of-N of several kernel geometries on one
        input: load drift on a small shared host hits every variant
        equally instead of biasing whichever measured last (the in-round
        order also alternates so periodic interference cannot phase-lock
        onto one variant), and the minimum is the robust estimator of
        the compute cost being compared.

        ``variants`` is ``[(label, local_skyline_batch kwargs), ...]``;
        the first entry is the baseline the speedup column is relative
        to."""
        m = jnp.ones(pts.shape[:2], jnp.bool_)
        fns = []
        for label, kw in variants:
            f = jax.jit(lambda p, q, kw=dict(kw): local_skyline_batch(
                p, q, **kw))
            jax.block_until_ready(f(pts, m))  # warmup/compile
            fns.append((label, f))
        best = {label: float("inf") for label, _ in variants}
        for r in range(repeat):
            for label, f in (fns if r % 2 == 0 else fns[::-1]):
                t0 = _time.perf_counter()
                jax.block_until_ready(f(pts, m))
                best[label] = min(best[label], _time.perf_counter() - t0)
        n_rows = pts.shape[0] * pts.shape[1]
        base = best[variants[0][0]]
        for label, t in best.items():
            extra = f"rows_per_s={n_rows / t:.3e}"
            if label != variants[0][0]:
                extra += f";speedup={base / t:.2f}x"
            emit(f"local_phase/{label}/{tag}", t * 1e6, extra)
        return best

    def geo(impl, wtile=0, capacity=cap, block=blk):
        return dict(capacity=capacity, block=block, impl=impl,
                    wtile=wtile)

    for n in ((n_max,) if quick else (4096, n_max)):
        pts = generate("uniform", jax.random.PRNGKey(21), n, d)[None]
        best = bench(f"n={n}", pts,
                     [("perpair", geo("perpair")), ("jnp", geo("jnp"))])
        if n == n_max:
            speedup = best["perpair"] / best["jnp"]

    # the parallel pipeline's local-stage shape: P partitions, one dispatch
    psz = n_max // parts
    bpts = generate("uniform", jax.random.PRNGKey(22),
                    parts * psz, d).reshape(parts, psz, d)
    bench(f"p={parts},n={psz}", bpts,
          [("perpair", geo("perpair")), ("jnp", geo("jnp"))])

    # interpret-mode Pallas body (CPU validation path) at a small size —
    # the row tracks the kernel body's cost, emulation is not meant to win
    ipts = generate("uniform", jax.random.PRNGKey(23), 512, d)[None]
    bench("n=512", ipts,
          [("perpair", geo("perpair", capacity=512, block=128)),
           ("jnp", geo("jnp", capacity=512, block=128)),
           ("interpret", geo("interpret", capacity=512, block=128))],
          repeat=5)

    # --- window-tile panel: tile width is pure schedule (every variant
    # is bit-identical), so these rows isolate the residency/perf trade
    tpts = generate("uniform", jax.random.PRNGKey(24), n_max, d)[None]
    bench(f"tiles,n={n_max}", tpts,
          [("jnp_untiled", geo("jnp", wtile=0)),
           (f"jnp_t{blk}", geo("jnp", wtile=blk)),
           (f"jnp_t{2 * blk}", geo("jnp", wtile=2 * blk))],
          repeat=5 if quick else 11)
    # W >> block stress shape: capacity 16384 at block 512 is the
    # geometry whose untiled window test (W x BC = 8.4M lanes resident)
    # busts the 16 MiB VMEM cap; tiled at 512 it passes (see the
    # `sweep_tiled` verifier cell)
    bench("stress,W=16384,b=512", tpts,
          [("untiled", geo("jnp", capacity=16_384, block=512)),
           ("t512", geo("jnp", wtile=512, capacity=16_384, block=512))],
          repeat=3 if quick else 5)

    # --- the autotuner's pick on THIS host vs the hand-set default:
    # b256/t0 is always in the candidate grid, and the tuner selects the
    # argmin over bitwise-verified candidates, so tuned <= default holds
    # by construction — the assert guards the selection logic itself
    from repro.kernels.tuning import calibrate_kernels
    rep = calibrate_kernels(
        None, ds=(d,), n=4096 if quick else n_max, p=parts, capacity=cap,
        blocks=(128, 256) if quick else (128, 256, 512),
        repeat=3, apply=False, verify=not quick)
    entry = rep["table"].lookup("sweep", d, jnp.float32)
    assert entry is not None, "autotuner produced no sweep entry"
    times = rep["keys"][f"sweep/d={d}/dtype=float32"]["times_us"]
    default_us = times[f"b{blk}/t0"]
    emit(f"local_phase/autotuned/n={n_max}", entry.time_us,
         f"block={entry.block};wtile={entry.wtile};"
         f"default_us={default_us:.2f}")
    assert entry.time_us <= default_us, (
        f"autotuned pick ({entry.block}, {entry.wtile}) slower than the "
        f"hand-set default (block={blk}, untiled): "
        f"{entry.time_us} > {default_us} us")
    return speedup


def kernel_microbench():
    """Dominance-kernel micro-benchmark: jnp path vs full-matrix oracle."""
    from repro.kernels.dominance import dominated_mask, dominated_mask_ref
    rng = np.random.default_rng(0)
    for (c, r, d) in [(4096, 4096, 4), (16384, 8192, 4), (8192, 8192, 7)]:
        cands = jnp.asarray(rng.random((c, d)), jnp.float32)
        refs = jnp.asarray(rng.random((r, d)), jnp.float32)
        f = jax.jit(lambda a, b: dominated_mask(a, b, impl="jnp"))
        t = timeit(f, cands, refs)
        tests_per_s = c * r / t
        emit(f"kernel/dominance/c={c},r={r},d={d}", t * 1e6,
             f"dom_tests_per_s={tests_per_s:.3e}")
    # oracle comparison at a size the full matrix tolerates
    cands = jnp.asarray(rng.random((2048, 4)), jnp.float32)
    refs = jnp.asarray(rng.random((2048, 4)), jnp.float32)
    f_ref = jax.jit(lambda a, b: dominated_mask_ref(a, b))
    emit("kernel/dominance_ref/c=2048,r=2048,d=4",
         timeit(f_ref, cands, refs) * 1e6, "full-matrix oracle")


def kernel_autotune(quick=False, path="results/kernel_tuning.json"):
    """The kernel-geometry calibration pass: run
    `repro.kernels.tuning.calibrate_kernels` on the live topology, emit
    one row per measured candidate, and persist the winning table as the
    JSON artifact CI uploads (and serve loads via ``--tuning`` /
    ``$REPRO_KERNEL_TUNING``).

    Fails — by raising, which `benchmarks.run` records and turns into a
    non-zero exit — if the table comes back empty or any measured
    candidate diverged bitwise from the per-pair reference: a tuning
    pass that cannot prove its geometries exact must not ship a table.
    Returns the number of tuned entries.
    """
    from repro.kernels.tuning import calibrate_kernels

    rep = calibrate_kernels(
        None, ds=(4,) if quick else (2, 4, 8),
        n=4096 if quick else 16_384, p=4 if quick else 8,
        blocks=(128, 256) if quick else (128, 256, 512),
        repeat=2 if quick else 3, apply=False, verify=True, path=path)
    table = rep["table"]
    for key, rec in sorted(rep["keys"].items()):
        for cand, us in sorted(rec["times_us"].items()):
            entry = table.entries.get(key)
            won = (entry is not None
                   and cand == (f"b{entry.block}/t{entry.wtile}"
                                if key.startswith("sweep")
                                else f"b{entry.block}"))
            emit(f"kernel_autotune/{key}/{cand}", us,
                 f"bitwise_ok={rec['bitwise_ok'][cand]}"
                 + (";winner" if won else ""))
    assert len(table) > 0, "calibration produced an empty tuning table"
    assert not rep["divergent"], (
        f"tuned candidates diverged bitwise from the reference: "
        f"{rep['divergent']}")
    emit("kernel_autotune/table", float(len(table)),
         f"path={rep.get('path', '')};impl={rep['impl']}")
    return len(table)


def throughput_sharded(q=4, n=32768, d=4, devices=None, repeat=4):
    """Engine dispatch at large N: vmap-only vs the 2-D (queries x
    workers) sharded program, per paper §partition-parallel regime.

    Runs in a subprocess with forced host-platform devices (the parent
    process keeps its single default device). The device count defaults
    to min(physical cores, 8): virtual devices beyond the core count
    only measure scheduler thrash, not partition parallelism. Every
    (queries x workers) factoring of the device count is measured so the
    row set shows where query-level vs tuple-level sharding pays; the
    `best` row carries the headline speedup over vmap-only.
    """
    import json
    import os
    import subprocess
    import sys
    import textwrap
    if devices is None:
        # largest power of two <= min(cores, 8): every (1, W) / (Q, 1) /
        # (2, W/2) factoring then divides cfg's p=8 partitions, and we
        # never oversubscribe cores (virtual devices beyond the physical
        # count measure scheduler thrash, not partition parallelism)
        devices = max(2, 1 << (min(os.cpu_count() or 2, 8).bit_length() - 1))
    code = textwrap.dedent(f"""
        import json, time, jax, numpy as np
        from repro.core.datagen import generate
        from repro.core.parallel import SkyConfig
        from repro.launch.mesh import make_engine_mesh
        from repro.serve.engine import SkylineEngine
        q, n, d = {q}, {n}, {d}
        cfg = SkyConfig(strategy="sliced", p=8, capacity=4096, block=256,
                        bucket_factor=1.5)
        queries = [generate("uniform", jax.random.PRNGKey(i), n, d)
                   for i in range(q)]
        ndev = len(jax.devices())
        engines = {{"vmap": SkylineEngine(cfg, min_n_bucket=n)}}
        meshes = [(ndev, 1), (1, ndev)] + (
            [(2, ndev // 2)] if ndev >= 4 else [])
        for qa, wa in meshes:
            engines[f"{{qa}}x{{wa}}"] = SkylineEngine(
                cfg, min_n_bucket=n, mesh=make_engine_mesh(qa, wa),
                shard_threshold_n=1)
        def go(engine):  # answers leave the device, as a serving loop does
            return [np.asarray(buf.points)
                    for buf, _ in engine.run(queries)]
        for e in engines.values():
            go(e)  # warmup/compile
        # interleaved rounds: clock/load drift during the run hits every
        # variant equally instead of biasing whichever ran last
        out = {{name: [] for name in engines}}
        for _ in range({repeat}):
            for name, e in engines.items():
                t0 = time.perf_counter(); go(e)
                out[name].append(time.perf_counter() - t0)
        for name, e in engines.items():
            assert name == "vmap" or e.sharded_dispatched > 0
        print("RESULT " + json.dumps(
            {{name: min(ts) for name, ts in out.items()}}))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1800)
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.loads([ln for ln in r.stdout.splitlines()
                      if ln.startswith("RESULT ")][-1][len("RESULT "):])
    t_vmap = res.pop("vmap")
    emit(f"throughput_sharded/vmap/q={q},n={n},devices={devices}",
         t_vmap * 1e6, f"queries_per_sec={q / t_vmap:.2f}")
    for name, t in res.items():
        emit(f"throughput_sharded/mesh={name}/q={q},n={n}", t * 1e6,
             f"queries_per_sec={q / t:.2f};speedup={t_vmap / t:.2f}x")
    best = min(res, key=res.get)
    emit(f"throughput_sharded/best/q={q},n={n},devices={devices}",
         res[best] * 1e6,
         f"mesh={best};speedup={t_vmap / res[best]:.2f}x")
    return t_vmap / res[best]


def streaming_maintenance(n=16384, d=4, chunk_counts=(2, 4, 8), repeat=3):
    """Streaming skyline serving: incremental `SkylineState` maintenance
    vs full recompute per chunk.

    A dataset of n tuples arrives in k equal chunks; after every chunk
    the serving layer must expose the current front. The *recompute*
    strategy answers each chunk by re-running the fused one-shot program
    over everything seen so far (a masked prefix of a fixed (n, d)
    buffer, so all k calls share ONE compiled program — no retrace cost
    in the measurement); the *incremental* strategy feeds the chunk into
    the device-resident state (`insert_chunk`) and snapshots
    (`finalize`). Both materialize every intermediate front, as a
    serving loop does, and both end bit-for-bit at the same answer
    (asserted). Emits chunks/sec per strategy and the speedup; returns
    the speedup at the largest chunk count.
    """
    from repro.core.incremental import (finalize_fn, init_state,
                                        insert_chunk_fn)
    from repro.core.parallel import fused_skyline_fn

    cfg = SkyConfig(strategy="sliced", p=8, capacity=1024, block=256,
                    bucket_factor=1.5)
    pts = generate("uniform", jax.random.PRNGKey(11), n, d)
    key = jax.random.PRNGKey(0)
    oneshot = fused_skyline_fn(cfg)
    row = jnp.arange(n)

    speedup = None
    for k in chunk_counts:
        csz = n // k
        chunks = [pts[i * csz:(i + 1) * csz] for i in range(k)]
        cmask = jnp.ones((csz,), jnp.bool_)
        ins = insert_chunk_fn(cfg)
        fin = finalize_fn(cfg)

        def incremental():
            state = init_state(cfg, d)
            fronts = []
            for i, c in enumerate(chunks):
                state, _ = ins(state, c, cmask,
                               jax.random.fold_in(key, i))
                fronts.append(np.asarray(fin(state).points))
            return fronts

        def recompute():
            fronts = []
            for i in range(k):
                m = row < (i + 1) * csz
                buf, _ = oneshot(pts, m, key)
                fronts.append(np.asarray(buf.points))
            return fronts

        # warmup/compile, and assert the two strategies agree bitwise
        np.testing.assert_array_equal(incremental()[-1], recompute()[-1])
        t_inc = timeit(incremental, warmup=0, repeat=repeat)
        t_rec = timeit(recompute, warmup=0, repeat=repeat)
        speedup = t_rec / t_inc
        emit(f"streaming/recompute/n={n},chunks={k}", t_rec * 1e6,
             f"chunks_per_sec={k / t_rec:.1f}")
        emit(f"streaming/incremental/n={n},chunks={k}", t_inc * 1e6,
             f"chunks_per_sec={k / t_inc:.1f};speedup={speedup:.2f}x")
    return speedup


def sliding_window(n=16384, d=4, epoch_counts=(2, 4, 8, 16), repeat=3):
    """Sliding-window skyline serving (the panel: speedup by epoch
    count): epoch-ring expiry (`WindowedSkylineState` — O(1) tail drop +
    head-epoch insert + merge-on-read) vs recomputing the whole window
    per tick.

    A stream of 2E chunks of n/E tuples arrives; the serving layer must
    expose the Pareto front of the last E chunks after every tick, so
    the second half of the run expires one epoch per tick. The
    *recompute* strategy reassembles the window into a fixed (n, d)
    buffer (one compiled one-shot program for all ticks; the host-side
    roll is part of its serving loop) and re-runs the fused pipeline
    over all n window tuples; the *ring* strategy runs ONE fused tick
    dispatch (`window_tick_fn`: rotate the ring + insert only the n/E
    arrivals + merge-on-read over the E packed epoch antichains), its
    epoch slots sized to the per-epoch retained candidates rather than
    the whole window budget (``epoch_capacity``). Both materialize every
    tick's front and end bit-for-bit at the same answer (asserted).
    Emits ticks/sec per strategy and epoch count; returns the speedup at
    E=8 (or the largest measured count below it)."""
    from repro.core.parallel import fused_skyline_fn
    from repro.core.windowed import init_window_state, window_tick_fn

    data = np.asarray(generate("uniform", jax.random.PRNGKey(13), 2 * n,
                               d))
    key = jax.random.PRNGKey(0)
    speedups = {}
    for e in epoch_counts:
        # capacity must hold the merge-on-read *union* of per-epoch
        # fronts (~E x per-epoch skyline; ~1.4k at E=16 on this data) —
        # the same communicate-the-local-skylines bound the one-shot
        # merge has — so size it to the window being served (both
        # strategies share the cfg; overflow is asserted off below)
        cfg = SkyConfig(strategy="sliced", p=8,
                        capacity=1024 if e <= 8 else 2048, block=256,
                        bucket_factor=1.5)
        oneshot = fused_skyline_fn(cfg)
        csz = n // e
        ticks = 2 * e
        chunks = [jnp.asarray(data[t * csz:(t + 1) * csz])
                  for t in range(ticks)]
        cmask = jnp.ones((csz,), jnp.bool_)
        tick = window_tick_fn(cfg)

        def ring():
            # per-epoch fronts stay far below the window budget: 256
            # retained-candidate rows per epoch are ample for n/E
            # uniform arrivals (the final overflow flag is asserted off)
            state = init_window_state(cfg, d, epochs=e,
                                      epoch_capacity=256)
            fronts = []
            for t in range(ticks):
                state, front, _ = tick(state, chunks[t], cmask,
                                       jax.random.fold_in(key, t),
                                       jnp.bool_(t > 0))
                fronts.append(np.asarray(front.points))
            assert not bool(front.overflow)
            return fronts

        buf = np.empty((n, d), np.float32)
        row = jnp.arange(n)

        def recompute():
            fronts = []
            for t in range(ticks):
                lo = max(t - e + 1, 0) * csz
                hi = (t + 1) * csz
                buf[: hi - lo] = data[lo:hi]
                m = row < (hi - lo)
                out, _ = oneshot(jnp.asarray(buf), m, key)
                fronts.append(np.asarray(out.points))
            return fronts

        # warmup/compile, and assert the strategies agree bitwise at
        # every tick (partial window, full window, and expiring ticks)
        fr, fq = ring(), recompute()
        for a, b in zip(fr, fq):
            np.testing.assert_array_equal(a, b)
        # interleaved best-of-N (alternating order): load drift on the
        # small shared host hits both strategies equally instead of
        # biasing whichever measured last
        import time as _time
        best = {"ring": float("inf"), "recompute": float("inf")}
        pairs = [("ring", ring), ("recompute", recompute)]
        for r in range(repeat):
            for name, fn in (pairs if r % 2 == 0 else pairs[::-1]):
                t0 = _time.perf_counter()
                fn()
                best[name] = min(best[name], _time.perf_counter() - t0)
        t_ring, t_rec = best["ring"], best["recompute"]
        speedups[e] = t_rec / t_ring
        emit(f"sliding_window/recompute/n={n},epochs={e}", t_rec * 1e6,
             f"ticks_per_sec={ticks / t_rec:.1f}")
        emit(f"sliding_window/ring/n={n},epochs={e}", t_ring * 1e6,
             f"ticks_per_sec={ticks / t_ring:.1f};"
             f"speedup={speedups[e]:.2f}x")
    at8 = max((e for e in speedups if e <= 8), default=max(speedups))
    return speedups[at8]


def serving_latency(bursts=12, width=4, n=1024, d=4, mean_gap_ms=12.0,
                    seed=0):
    """Async serve-loop latency: p50/p99 request latency under Poisson
    burst arrivals with dispatch-ahead ON (depth=2) vs OFF (depth=1).

    ``bursts`` waves of ``width`` `SkylineRequest`s (fixed (n, d) shape,
    so every wave hits the same compiled program — the engine is warmed
    before the clock starts) arrive with exponential inter-burst gaps;
    both depths replay the IDENTICAL arrival schedule at the same
    offered load. With ``depth=1`` nothing is staged until the previous
    wave fully completed — the post-completion host pack is a dead
    bubble on the request's critical path; with ``depth=2`` wave k+1 is
    packed and dispatched while the device still executes wave k, so
    the bubble hides behind device compute (fully, given a second host
    core; even single-core the pre-dispatched wave starts without a
    thread-handoff gap). Emits p50/p99 per depth (the us_per_call
    column is p99) plus the measured stage/compute overlap; returns
    p99(depth=1) / p99(depth=2) — above 1.0 means dispatch-ahead
    lowered tail latency.
    """
    from repro.serve.api import SkylineRequest
    from repro.serve.engine import SkylineEngine
    from repro.serve.loop import ServeLoop
    import time as _time

    cfg = SkyConfig(strategy="sliced", p=4, capacity=512, block=64,
                    bucket_factor=4.0)
    engine = SkylineEngine(cfg)
    rng = np.random.default_rng(seed)
    requests = bursts * width
    datas = [np.asarray(rng.random((n, d)), np.float32)
             for _ in range(requests)]
    arrivals = np.repeat(
        np.cumsum(rng.exponential(mean_gap_ms / 1e3, bursts)), width)
    # warm the compile caches (pack/pipeline/unpack) outside the clock,
    # for every q-bucket a wave of up to ``width`` queries can hit
    for w in range(1, width + 1):
        engine.submit_many([SkylineRequest(data=datas[i])
                            for i in range(w)])

    p99s = {}
    for depth in (1, 2):
        with ServeLoop(engine, depth=depth, max_wave=width) as loop:
            t0 = _time.monotonic()
            tickets = []
            for x, at in zip(datas, arrivals):
                while _time.monotonic() - t0 < at:
                    _time.sleep(0.0002)
                tickets.append(loop.submit(SkylineRequest(data=x)))
            loop.drain()
        lats = sorted(t.latency for t in tickets if t.status == "ok")
        assert len(lats) == requests  # no deadlines -> nothing sheds
        p50 = lats[len(lats) // 2]
        p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
        p99s[depth] = p99
        emit(f"serving_latency/depth={depth}/req={requests},n={n}",
             p99 * 1e6,
             f"p50_ms={p50 * 1e3:.2f};p99_ms={p99 * 1e3:.2f};"
             f"waves={loop.stats['waves']};"
             f"overlap_s={loop.stats['stage_overlap_s']:.3f}")
    emit(f"serving_latency/dispatch_ahead_gain/req={requests},n={n}",
         (p99s[1] - p99s[2]) * 1e6,
         f"p99_off_over_on={p99s[1] / p99s[2]:.2f}x")
    return p99s[1] / p99s[2]


def calibration(devices=None, d=4):
    """`calibrate_shard_threshold` on a forced multi-device topology:
    measures vmap vs every 2-D (queries x workers) factoring at a few N
    buckets and reports both the data-derived ``shard_threshold_n`` and
    the per-bucket winning factoring (stored on the engine and consulted
    at dispatch). Runs in a subprocess so the parent process keeps its
    single default device."""
    import json
    import os
    import subprocess
    import sys
    import textwrap
    if devices is None:
        devices = max(2, 1 << (min(os.cpu_count() or 2, 8).bit_length() - 1))
    code = textwrap.dedent(f"""
        import json, jax
        from repro.core.parallel import SkyConfig
        from repro.launch.mesh import engine_mesh_shape, make_engine_mesh
        from repro.serve.engine import SkylineEngine, calibrate_shard_threshold
        cfg = SkyConfig(strategy="sliced", p=8, capacity=4096, block=256,
                        bucket_factor=1.5)
        qa, wa = engine_mesh_shape(cfg.p)
        engine = SkylineEngine(cfg, mesh=make_engine_mesh(qa, wa))
        rep = calibrate_shard_threshold(engine, d={d},
                                        bucket_sizes=(1024, 4096, 16384))
        assert engine.shard_threshold_n == rep["threshold_n"]
        def _parse(v):  # "QxW:mode" report strings
            qw, mode = v.split(":")
            qa, wa = qw.split("x")
            return (int(qa), int(wa), mode)
        assert {{int(k): _parse(v)
                for k, v in rep["factorings"].items()}} == engine.factorings
        print("RESULT " + json.dumps(rep))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1800)
    assert r.returncode == 0, r.stderr[-2000:]
    rep = json.loads([ln for ln in r.stdout.splitlines()
                      if ln.startswith("RESULT ")][-1][len("RESULT "):])
    for nb, t in sorted(rep["measurements"].items(), key=lambda kv:
                        int(kv[0])):
        facts = ";".join(f"t[{name}]={tf:.4f}"
                         for name, tf in sorted(t["factorings"].items()))
        emit(f"calibration/bucket={nb},devices={devices}",
             t["vmap"] * 1e6,
             f"vmap_s={t['vmap']:.4f};sharded_s={t['sharded']:.4f};"
             f"sharded_wins={t['sharded'] < t['vmap']};"
             f"best_factoring={t['best_factoring']};"
             f"best_merge={t['best_merge']};"
             f"t[merge_flat]={t['merge']['flat']:.4f};"
             f"t[merge_tree]={t['merge']['tree']:.4f};{facts}")
    emit(f"calibration/threshold/devices={devices}",
         float(rep["threshold_n"]),
         f"shard_threshold_n={rep['threshold_n']};factorings="
         + ",".join(f"{nb}:{f}"
                    for nb, f in sorted(rep["factorings"].items(),
                                        key=lambda kv: int(kv[0]))))
    return rep["threshold_n"]


def merge_scaling(n_per_worker=12_500, d=3, device_counts=None, repeat=4):
    """Flat all_gather union vs the ⌈log₂(W)⌉-round pruning ppermute
    tree, by worker count: wall time plus the modeled per-worker wire
    bytes each schedule moves across the device boundary.

    Weak scaling in the output-sensitive regime the tree merge is for:
    ``n = n_per_worker x W`` uniform rows (small skyline relative to
    the union), one partition per worker, so per-worker bucket rows
    C_loc stay constant and the flat union a worker materializes —
    and must sort/compact — grows as O(p x C_loc) ∝ W, while the tree
    touches O(capacity) rows per round over ⌈log₂(W)⌉ + 2 rounds —
    the communication bound the hierarchical merge exists to provide.

    One subprocess per device count (the parent keeps its single
    default device). Inside each, the identical fused pipeline runs
    under ``merge='flat'`` and ``merge='tree'`` on the same data and
    the results are asserted bit-for-bit equal — equality is the hard
    acceptance; wall time on forced host devices is advisory (a CPU
    'collective' is a memcpy, so the wire-byte model, not the clock,
    carries the scaling argument).
    """
    import json
    import os
    import subprocess
    import sys
    import textwrap

    from repro.core.parallel import merge_rounds
    if device_counts is None:
        # the scaling argument rides the wire-byte model, not the clock,
        # so forced host devices beyond the core count are fine here
        # (unlike throughput_sharded, which measures wall time)
        device_counts = (2, 4, 8)
    last_ratio = 1.0
    for devices in device_counts:
        code = textwrap.dedent(f"""
            import dataclasses, json, time, jax, numpy as np
            from repro.core import SkyConfig, parallel_skyline
            from repro.core.datagen import generate
            from repro.core.parallel import fused_skyline_fn
            from repro.launch.mesh import make_worker_mesh
            d = {d}
            w = len(jax.devices())
            assert w == {devices}, w
            n = {n_per_worker} * w  # weak scaling: fixed per-worker load
            mesh = make_worker_mesh()
            # capacity sized to hold the union of local skylines (so
            # neither schedule overflows — under overflow the two merge
            # orders may legitimately retain different counts, and the
            # bitwise assertion below is the suite's hard acceptance)
            # while staying far below p x C_loc — the output-sensitive
            # gap the tree exploits
            base = SkyConfig(strategy="sliced", p=w, capacity=1024,
                             block=256, bucket_factor=2.0)
            pts = generate("uniform", jax.random.PRNGKey(11), n, d)
            mask = jax.numpy.ones((n,), bool)
            key = jax.random.PRNGKey(0)
            cfgs = {{m: dataclasses.replace(base, merge=m)
                     for m in ("flat", "tree")}}
            fns = {{m: fused_skyline_fn(c, mesh) for m, c in cfgs.items()}}
            bufs = {{m: jax.block_until_ready(f(pts, mask, key)[0])
                     for m, f in fns.items()}}  # warmup/compile + answers
            # the hard acceptance: both schedules, identical bits
            np.testing.assert_array_equal(np.asarray(bufs["flat"].points),
                                          np.asarray(bufs["tree"].points))
            np.testing.assert_array_equal(np.asarray(bufs["flat"].mask),
                                          np.asarray(bufs["tree"].mask))
            assert int(bufs["flat"].count) == int(bufs["tree"].count)
            assert not bool(bufs["flat"].overflow)
            assert not bool(bufs["tree"].overflow)
            # interleaved timing rounds: drift hits both modes equally
            out = {{m: [] for m in fns}}
            for _ in range({repeat}):
                for m, f in fns.items():
                    t0 = time.perf_counter()
                    jax.block_until_ready(f(pts, mask, key))
                    out[m].append(time.perf_counter() - t0)
            # C_loc exactly as partition_stage/local_stage size it: the
            # per-partition bucket rows every worker contributes to the
            # flat union
            cap_b = base.bucket_capacity or max(
                1, int(base.bucket_factor * -(-n // base.p)) + 1)
            c_loc = base.local_capacity or cap_b
            print("RESULT " + json.dumps({{
                "flat_s": min(out["flat"]), "tree_s": min(out["tree"]),
                "p": base.p, "d": d, "n": n, "c_loc": c_loc,
                "capacity": base.capacity,
                "sky_count": int(bufs["flat"].count)}}))
        """)
        env = dict(os.environ)
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        env["JAX_PLATFORMS"] = "cpu"
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=1800)
        assert r.returncode == 0, r.stderr[-2000:]
        res = json.loads([ln for ln in r.stdout.splitlines()
                          if ln.startswith("RESULT ")][-1][len("RESULT "):])
        # modeled per-worker boundary bytes, mirroring resolve_merge's
        # model: flat materializes the (p, C_loc, d) union on every
        # worker; the tree moves a packed (cap, d+1) wire per round plus
        # the two broadcast legs (cap = min(p x C_loc, capacity))
        p, dd, c_loc = res["p"], res["d"], res["c_loc"]
        rounds = merge_rounds(devices)
        cap = min(p * c_loc, res["capacity"])
        flat_bytes = p * c_loc * dd * 4
        tree_bytes = (rounds + 2) * cap * (dd + 1) * 4
        last_ratio = flat_bytes / tree_bytes
        emit(f"merge_scaling/flat/devices={devices},n={res['n']}",
             res["flat_s"] * 1e6,
             f"wire_bytes={flat_bytes};sky={res['sky_count']}")
        emit(f"merge_scaling/tree/devices={devices},n={res['n']}",
             res["tree_s"] * 1e6,
             f"wire_bytes={tree_bytes};rounds={rounds};"
             f"bitwise_equal=True;"
             f"bytes_ratio={last_ratio:.2f}x;"
             f"speedup={res['flat_s'] / res['tree_s']:.2f}x")
    return last_ratio


def throughput_queries_per_sec(q=32, n=64, d=4, repeat=9):
    """Engine-batched vs per-query-loop throughput (serving regime).

    Q small queries answered (a) by a Python loop of `parallel_skyline`
    calls — one dispatch each through the already-compiled fused program,
    with each answer materialized before the next query is served, as a
    per-request serving loop does — and (b) by one `SkylineEngine.run`
    call — a single vmapped dispatch, all answers materialized at the
    end. Emits queries/sec for both and the speedup."""
    import time as _time

    from repro.core.parallel import parallel_skyline
    from repro.serve.engine import SkylineEngine

    cfg = SkyConfig(strategy="sliced", p=4, capacity=n, block=256,
                    bucket_factor=2.0)
    queries = [generate("uniform", jax.random.PRNGKey(i), n, d)
               for i in range(q)]
    engine = SkylineEngine(cfg, min_n_bucket=n)

    def loop():
        out = []
        for pts in queries:
            buf, _ = parallel_skyline(pts, cfg=cfg)
            out.append(np.asarray(buf.points))  # answer leaves the device
        return out

    def batched():
        return [np.asarray(buf.points)
                for buf, _ in engine.run(queries)]

    def best_of(fn):
        fn()  # warmup/compile
        ts = []
        for _ in range(repeat):
            t0 = _time.perf_counter()
            fn()
            ts.append(_time.perf_counter() - t0)
        return min(ts)

    t_loop = best_of(loop)
    t_engine = best_of(batched)
    qps_loop = q / t_loop
    qps_engine = q / t_engine
    emit(f"throughput/loop/q={q},n={n},d={d}", t_loop * 1e6,
         f"queries_per_sec={qps_loop:.1f}")
    emit(f"throughput/engine/q={q},n={n},d={d}", t_engine * 1e6,
         f"queries_per_sec={qps_engine:.1f} "
         f"speedup={qps_engine / qps_loop:.2f}x")
    return qps_engine / qps_loop


def feed_memory(capacity=8192, q=8, chunk=256, d=4, feeds=16,
                quick=False):
    """Steady-state live device bytes and feeds/sec of the streaming
    hot path with buffer donation on vs off (`SkyConfig.donate`).

    The memory number is the compiled program's state-resident bytes —
    ``memory_analysis()`` arguments + outputs - aliased — i.e. the
    buffers XLA must hold simultaneously per in-flight feed. With
    donation on the state operand aliases its output and one copy is
    resident; with donation off input AND output copies coexist on
    every dispatch, which a depth-pipelined serve loop multiplies by
    its in-flight wave count. The >= 1.5x reduction at capacity >= 8k
    is asserted (a compile-time fact, not a timing), feeds/sec rides
    along as the no-regression check; the per-dispatch scratch
    (``temp``) is emitted too but excluded from the ratio — XLA reuses
    scratch across dispatches in either mode.
    """
    import dataclasses
    import time as _time

    from repro.core import incremental as inc

    if quick:
        q, feeds = 4, 8
    assert capacity >= 8192, "acceptance regime: capacity >= 8k"
    base = SkyConfig(strategy="sliced", p=4, capacity=capacity,
                     block=256, bucket_factor=1.5)
    pts = generate("anticorrelated", jax.random.PRNGKey(0),
                   q * chunk * feeds, d).reshape(feeds, q, chunk, d)
    mask = jnp.ones((q, chunk), bool)
    keys = jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(7), i))(jnp.arange(q))

    out = {}
    for donate in (True, False):
        cfg = dataclasses.replace(base, donate=donate)
        ins = inc.insert_chunk_batch_fn(cfg)
        state = inc.init_state(cfg, d, q=q)
        mem = ins.lower(state, pts[0], mask, keys).compile() \
            .memory_analysis()
        stats = {k: int(getattr(mem, f"{k}_size_in_bytes", 0) or 0)
                 for k in ("argument", "output", "temp", "alias")}
        live = stats["argument"] + stats["output"] - stats["alias"]
        # warmup (compile via the cached executable) then timed feeds;
        # the state is rebound every call — mandatory with donation on
        # (the old buffers are deleted), harmless off
        state, _ = ins(state, pts[0], mask, keys)
        jax.block_until_ready(state.points)
        t0 = _time.perf_counter()
        for i in range(1, feeds):
            state, _ = ins(state, pts[i], mask, keys)
        jax.block_until_ready(state.points)
        fps = (feeds - 1) / (_time.perf_counter() - t0)
        out[donate] = (live, stats, fps)
        emit(f"feed_memory/donate={'on' if donate else 'off'}/"
             f"capacity={capacity},q={q},chunk={chunk},d={d}",
             1e6 / fps,
             f"live_bytes={live};temp_bytes={stats['temp']};"
             f"alias_bytes={stats['alias']};feeds_per_sec={fps:.1f}")

    ratio = out[False][0] / max(out[True][0], 1)
    fps_ratio = out[True][2] / out[False][2]
    emit(f"feed_memory/ratio/capacity={capacity},q={q}", 0.0,
         f"live_bytes_reduction={ratio:.2f}x;"
         f"feeds_per_sec_ratio={fps_ratio:.2f}x")
    # the acceptance floor: donation must collapse the A/B state copies
    assert ratio >= 1.5, (
        f"donation live-bytes reduction {ratio:.2f}x below the 1.5x "
        f"floor at capacity={capacity} "
        f"(on={out[True][0]}, off={out[False][0]})")
    return ratio
